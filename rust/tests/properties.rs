//! Property-based tests on the coordinator's pure invariants, using the
//! in-repo `util::proptest` harness (DESIGN.md §8).

use fluid::data::partition;
use fluid::dropout::mask::kept_count;
use fluid::dropout::{
    threshold, InvariantConfig, InvariantDropout, MaskSet, OrderedDropout, RandomDropout,
};
use fluid::engine::{ClientArrival, EventScheduler, SyncMode};
use fluid::fl::{
    fedavg, fedavg_into, staleness_discount, unpack, AggScratch, AggregateMode, ClientUpdate,
    Codec, Compression, DeltaPayload, PackedResult, QuantUpdate, SparseUpdate, UpdateCodec,
};
use fluid::jsonlite::{self, Json};
use fluid::model::ModelSpec;
use fluid::straggler::{detect_stragglers, snap_rate, AdaptConfig, AdaptMode, RateController};
use fluid::tensor::Tensor;
use fluid::util::proptest::{check, shrink_vec, Config, Gen};

fn spec_with_groups(sizes: &[usize]) -> ModelSpec {
    // synthesize a manifest with one dense layer per group
    let mut params = String::new();
    let mut masks = String::new();
    let mut groups = String::new();
    let mut dins = String::new();
    for (i, &n) in sizes.iter().enumerate() {
        let fan_in = 4 + i;
        params.push_str(&format!(
            r#"{{"name": "fc{i}_w", "shape": [{fan_in}, {n}]}}, {{"name": "fc{i}_b", "shape": [{n}]}}, "#
        ));
        masks.push_str(&format!(r#"{{"name": "fc{i}", "size": {n}}}, "#));
        groups.push_str(&format!(r#""fc{i}", "#));
        dins.push_str(&format!(r#""fc{i}_w", "#));
    }
    let text = format!(
        r#"{{
 "model": "syn", "batch_size": 2, "x_shape": [2, 4], "x_dtype": "f32",
 "num_classes": 3,
 "params": [{} {{"name": "out_w", "shape": [4, 3]}}, {{"name": "out_b", "shape": [3]}}],
 "masks": [{}],
 "delta_groups": [{}],
 "delta_inputs": [{}],
 "artifacts": {{"train": "t", "eval": "e", "delta": "d"}},
 "train_outputs": []
}}"#,
        params,
        masks.trim_end_matches(", "),
        groups.trim_end_matches(", "),
        dins.trim_end_matches(", "),
    );
    ModelSpec::from_json_str(&text, std::path::Path::new("/tmp")).unwrap()
}

#[test]
fn prop_mask_sizes_exact_for_all_policies() {
    check(
        Config { cases: 80, ..Default::default() },
        |g: &mut Gen| {
            let ngroups = g.usize_in(1, 4);
            let sizes: Vec<usize> = (0..ngroups).map(|_| g.usize_in(1, 64)).collect();
            let r = g.f32_in(0.05, 1.0) as f64;
            let seed = g.rng.next_u64();
            (sizes, r, seed)
        },
        |_| vec![],
        |(sizes, r, seed)| {
            let spec = spec_with_groups(sizes);
            let mut rd = RandomDropout::new(*seed);
            let mut od = OrderedDropout::new();
            for m in [rd.make_mask(&spec, *r), od.make_mask(&spec, *r)] {
                for (g, &n) in sizes.iter().enumerate() {
                    let want = kept_count(n, *r);
                    if m.kept(g) != want {
                        return Err(format!(
                            "group {g} size {n} r {r}: kept {} want {want}",
                            m.kept(g)
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_maskset_round_trips_extract_reinflate() {
    // MaskSet::from_keep is faithful to its keep decisions, and a
    // sub-model extract -> reinflate (zero-fill dropped neurons) is the
    // identity on kept values and exactly `v * mask` elementwise.
    check(
        Config { cases: 80, ..Default::default() },
        |g: &mut Gen| {
            let ngroups = g.usize_in(1, 4);
            let sizes: Vec<usize> = (0..ngroups).map(|_| g.usize_in(1, 48)).collect();
            let keep: Vec<Vec<bool>> = sizes
                .iter()
                .map(|&n| (0..n).map(|_| g.bool()).collect())
                .collect();
            let values: Vec<Vec<f32>> =
                sizes.iter().map(|&n| g.vec_f32(n, -3.0, 3.0)).collect();
            (sizes, keep, values)
        },
        |_| vec![],
        |(sizes, keep, values)| {
            let spec = spec_with_groups(sizes);
            let m = MaskSet::from_keep(&spec, keep);
            for (gi, (k, v)) in keep.iter().zip(values).enumerate() {
                // faithfulness: is_kept mirrors the keep vector, counts agree
                let want_kept = k.iter().filter(|&&b| b).count();
                if m.kept(gi) != want_kept {
                    return Err(format!("group {gi}: kept {} want {want_kept}", m.kept(gi)));
                }
                for (i, &b) in k.iter().enumerate() {
                    if m.is_kept(gi, i) != b {
                        return Err(format!("group {gi} neuron {i}: is_kept mismatch"));
                    }
                }
                // extract the sub-model...
                let extracted: Vec<f32> = (0..v.len())
                    .filter(|&i| m.is_kept(gi, i))
                    .map(|i| v[i])
                    .collect();
                // ...and reinflate with zero-filled dropped neurons
                let mut reinflated = vec![0.0f32; v.len()];
                let mut cursor = 0usize;
                for i in 0..v.len() {
                    if m.is_kept(gi, i) {
                        reinflated[i] = extracted[cursor];
                        cursor += 1;
                    }
                }
                if cursor != extracted.len() {
                    return Err("reinflate consumed wrong element count".into());
                }
                let mask_t = &m.tensors()[gi];
                for i in 0..v.len() {
                    let want = v[i] * mask_t.data()[i];
                    if reinflated[i] != want {
                        return Err(format!(
                            "group {gi} neuron {i}: reinflated {} != v*mask {want}",
                            reinflated[i]
                        ));
                    }
                }
            }
            // aggregate bookkeeping is consistent with the per-group counts
            let total: usize = sizes.iter().sum();
            let kept: usize = (0..sizes.len()).map(|g| m.kept(g)).sum();
            let frac = kept as f64 / total as f64;
            if (m.keep_fraction() - frac).abs() > 1e-12 {
                return Err(format!("keep_fraction {} != {frac}", m.keep_fraction()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_invariant_fraction_monotone_in_threshold() {
    // invariant_fraction_at counts score < th, so it must be monotone
    // non-decreasing in th and hit {0, 1} at the extremes.
    check(
        Config { cases: 60, ..Default::default() },
        |g: &mut Gen| {
            let ngroups = g.usize_in(1, 3);
            let sizes: Vec<usize> = (0..ngroups).map(|_| g.usize_in(1, 32)).collect();
            let clients = g.usize_in(1, 5);
            let deltas: Vec<Vec<Vec<f32>>> = (0..clients)
                .map(|_| sizes.iter().map(|&n| g.vec_f32(n, 0.0, 2.0)).collect())
                .collect();
            let mut ths: Vec<f32> = (0..6).map(|_| g.f32_in(0.0, 2.5)).collect();
            ths.sort_by(|a, b| a.partial_cmp(b).unwrap());
            (sizes, deltas, ths)
        },
        |_| vec![],
        |(sizes, deltas, ths)| {
            let spec = spec_with_groups(sizes);
            let mut p = InvariantDropout::new(&spec, InvariantConfig::default());
            let per_client: Vec<Vec<Tensor>> = deltas
                .iter()
                .map(|c| {
                    c.iter()
                        .map(|v| Tensor::from_vec(&[v.len()], v.clone()))
                        .collect()
                })
                .collect();
            p.observe(&per_client);
            let mut prev = -1.0f64;
            for &th in ths {
                let f = p.invariant_fraction_at(th);
                if !(0.0..=1.0).contains(&f) {
                    return Err(format!("fraction {f} outside [0,1] at th={th}"));
                }
                if f < prev {
                    return Err(format!("not monotone: {prev} -> {f} at th={th}"));
                }
                prev = f;
            }
            if p.invariant_fraction_at(0.0) != 0.0 {
                return Err("th=0 must make nothing invariant (strict <)".into());
            }
            if p.invariant_fraction_at(f32::INFINITY) != 1.0 {
                return Err("th=inf must make everything invariant".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_plain_fedavg_preserves_constant_consensus() {
    // if every client reports the same params, aggregation returns them
    check(
        Config { cases: 60, ..Default::default() },
        |g: &mut Gen| {
            let sizes = vec![g.usize_in(1, 16)];
            let v = g.f32_in(-5.0, 5.0);
            let nclients = g.usize_in(1, 6);
            let weights: Vec<f64> =
                (0..nclients).map(|_| g.f32_in(0.1, 10.0) as f64).collect();
            (sizes, v, weights)
        },
        |_| vec![],
        |(sizes, v, weights)| {
            let spec = spec_with_groups(sizes);
            let params: Vec<Tensor> = spec
                .params
                .iter()
                .map(|p| Tensor::full(&p.shape, *v))
                .collect();
            let updates: Vec<ClientUpdate> = weights
                .iter()
                .map(|&w| ClientUpdate {
                    payload: DeltaPayload::DenseF32(params.clone()),
                    weight: w,
                    mask: MaskSet::full(&spec),
                    staleness: 0,
                })
                .collect();
            for mode in [AggregateMode::Plain, AggregateMode::OwnershipWeighted] {
                let out = fedavg(&spec, &params, &updates, mode);
                for (t, p) in out.iter().zip(&params) {
                    for (a, b) in t.data().iter().zip(p.data()) {
                        if (a - b).abs() > 1e-4 {
                            return Err(format!("consensus broken: {a} vs {b}"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ownership_aggregation_keeps_untrained_at_global() {
    // elements dropped by EVERY client must stay exactly at the global value
    check(
        Config { cases: 60, ..Default::default() },
        |g: &mut Gen| {
            let n = g.usize_in(2, 24);
            let drop_idx = g.usize_in(0, n - 1);
            let nclients = g.usize_in(1, 5);
            let seed = g.rng.next_u64();
            (vec![n], drop_idx, nclients, seed)
        },
        |_| vec![],
        |(sizes, drop_idx, nclients, seed)| {
            let spec = spec_with_groups(sizes);
            let n = sizes[0];
            let global: Vec<Tensor> = spec
                .params
                .iter()
                .map(|p| Tensor::full(&p.shape, 0.5))
                .collect();
            let mut rng = fluid::util::prng::Pcg32::new(*seed, 5);
            let updates: Vec<ClientUpdate> = (0..*nclients)
                .map(|_| {
                    let mut keep = vec![true; n];
                    keep[*drop_idx] = false;
                    // clients may drop extra random neurons too
                    for k in keep.iter_mut() {
                        if rng.next_f32() < 0.2 {
                            *k = false;
                        }
                    }
                    keep[*drop_idx] = false;
                    ClientUpdate {
                        payload: DeltaPayload::DenseF32(
                            spec.params
                                .iter()
                                .map(|p| Tensor::full(&p.shape, 2.0))
                                .collect(),
                        ),
                        weight: 1.0,
                        mask: MaskSet::from_keep(&spec, &[keep]),
                        staleness: 0,
                    }
                })
                .collect();
            let out = fedavg(&spec, &global, &updates, AggregateMode::OwnershipWeighted);
            // fc0_w column drop_idx and fc0_b entry drop_idx stay 0.5
            let cols = n;
            let w = out[0].data();
            for row in 0..w.len() / cols {
                let x = w[row * cols + drop_idx];
                if (x - 0.5).abs() > 1e-6 {
                    return Err(format!("w[{row},{drop_idx}] = {x}, want 0.5"));
                }
            }
            let b = out[1].data()[*drop_idx];
            if (b - 0.5).abs() > 1e-6 {
                return Err(format!("b[{drop_idx}] = {b}"));
            }
            Ok(())
        },
    );
}

/// A spec whose first group uses the LSTM 4H gate layout (`lstm_w`
/// trailing dim = 4 x hidden) next to a plain dense group — both
/// column->neuron mappings the parallel aggregator must reproduce.
fn spec_with_gate(n0: usize, n1: usize) -> ModelSpec {
    let gates = 4 * n0;
    let text = format!(
        r#"{{
 "model": "syn_gate", "batch_size": 2, "x_shape": [2, 4], "x_dtype": "f32",
 "num_classes": 3,
 "params": [
   {{"name": "lstm_w", "shape": [3, {gates}]}}, {{"name": "lstm_b", "shape": [{gates}]}},
   {{"name": "fc0_w", "shape": [5, {n1}]}}, {{"name": "fc0_b", "shape": [{n1}]}},
   {{"name": "out_w", "shape": [4, 3]}}, {{"name": "out_b", "shape": [3]}}
 ],
 "masks": [{{"name": "lstm", "size": {n0}}}, {{"name": "fc0", "size": {n1}}}],
 "delta_groups": ["lstm", "fc0"],
 "delta_inputs": ["lstm_w", "fc0_w"],
 "artifacts": {{"train": "t", "eval": "e", "delta": "d"}},
 "train_outputs": []
}}"#
    );
    ModelSpec::from_json_str(&text, std::path::Path::new("/tmp")).unwrap()
}

/// The historical scalar fedavg, reimplemented verbatim from public
/// APIs: per-element neuron mapping, mask lookups, `vec![0.0; len]`
/// accumulators. The production `fedavg_into` must match it bit for bit.
fn reference_fedavg(
    spec: &ModelSpec,
    global: &[Tensor],
    updates: &[ClientUpdate],
    mode: AggregateMode,
) -> Vec<Tensor> {
    let eff = |u: &ClientUpdate| -> f64 {
        if u.staleness == 0 {
            u.weight
        } else {
            u.weight * staleness_discount(u.staleness)
        }
    };
    let group_of = |p_idx: usize| -> Option<(usize, usize)> {
        let p = &spec.params[p_idx];
        let prefix: &str = p.name.rsplit_once('_').map(|(a, _)| a).unwrap_or(&p.name);
        let g = spec.mask_index(prefix)?;
        let n = spec.masks[g].size;
        let cols = *p.shape.last()?;
        if cols == n {
            Some((g, 1))
        } else if cols == 4 * n {
            Some((g, 4))
        } else {
            None
        }
    };
    let mut out = Vec::with_capacity(global.len());
    for (pi, g_t) in global.iter().enumerate() {
        let group = match mode {
            AggregateMode::Plain => None,
            AggregateMode::OwnershipWeighted => group_of(pi),
        };
        let cols = *spec.params[pi].shape.last().unwrap_or(&1);
        let len = g_t.len();
        let mut acc = vec![0.0f64; len];
        let mut denom = vec![0.0f64; len];
        for u in updates {
            let w = eff(u);
            let data = u.dense_params()[pi].data();
            match group {
                None => {
                    for j in 0..len {
                        acc[j] += w * data[j] as f64;
                        denom[j] += w;
                    }
                }
                Some((gidx, span)) => {
                    let n = spec.masks[gidx].size;
                    for j in 0..len {
                        let col = j % cols;
                        let neuron = if span == 1 { col } else { col % n };
                        if u.mask.is_kept(gidx, neuron) {
                            acc[j] += w * data[j] as f64;
                            denom[j] += w;
                        }
                    }
                }
            }
        }
        let g_data = g_t.data();
        let new: Vec<f32> = (0..len)
            .map(|j| {
                if denom[j] > 0.0 {
                    (acc[j] / denom[j]) as f32
                } else {
                    g_data[j]
                }
            })
            .collect();
        out.push(Tensor::from_vec(g_t.shape(), new));
    }
    out
}

#[test]
fn prop_parallel_fedavg_bit_identical_to_scalar_reference() {
    // random cohorts (random masks, weights, staleness) through both
    // aggregate modes: the pooled parallel path must equal the scalar
    // reference bit for bit at every thread count, with one shared
    // scratch arena reused dirty across every case and shape
    let scratch = std::cell::RefCell::new(AggScratch::new());
    check(
        Config { cases: 48, ..Default::default() },
        |g: &mut Gen| {
            let n0 = g.usize_in(1, 6);
            let n1 = g.usize_in(1, 12);
            let nclients = g.usize_in(1, 5);
            let seed = g.rng.next_u64();
            (n0, n1, nclients, seed)
        },
        |_| vec![],
        |&(n0, n1, nclients, seed)| {
            let spec = spec_with_gate(n0, n1);
            let mut rng = fluid::util::prng::Pcg32::new(seed, 11);
            let rand_params = |rng: &mut fluid::util::prng::Pcg32| -> Vec<Tensor> {
                spec.params
                    .iter()
                    .map(|p| {
                        let len: usize = p.shape.iter().product();
                        Tensor::from_vec(
                            &p.shape,
                            (0..len).map(|_| rng.uniform(-2.0, 2.0)).collect(),
                        )
                    })
                    .collect()
            };
            let global = rand_params(&mut rng);
            let updates: Vec<ClientUpdate> = (0..nclients)
                .map(|_| {
                    let keep: Vec<Vec<bool>> = spec
                        .masks
                        .iter()
                        .map(|m| (0..m.size).map(|_| rng.next_f32() < 0.7).collect())
                        .collect();
                    ClientUpdate {
                        payload: DeltaPayload::DenseF32(rand_params(&mut rng)),
                        weight: rng.uniform(0.1, 5.0) as f64,
                        mask: MaskSet::from_keep(&spec, &keep),
                        staleness: (rng.next_u32() % 3) as usize,
                    }
                })
                .collect();
            for mode in [AggregateMode::Plain, AggregateMode::OwnershipWeighted] {
                let want = reference_fedavg(&spec, &global, &updates, mode);
                for threads in [1usize, 2, 4, 8] {
                    let mut s = scratch.borrow_mut();
                    let got = fedavg_into(&spec, &global, &updates, mode, threads, &mut s);
                    for (pi, (a, b)) in got.iter().zip(&want).enumerate() {
                        for (j, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
                            if x.to_bits() != y.to_bits() {
                                return Err(format!(
                                    "mode {mode:?} threads {threads} param {pi} elem {j}: \
                                     {x} ({:#010x}) vs {y} ({:#010x})",
                                    x.to_bits(),
                                    y.to_bits()
                                ));
                            }
                        }
                    }
                    s.recycle(got);
                }
            }
            Ok(())
        },
    );
}

/// The generated specs above sit inside one parallel chunk; this case
/// crosses the fixed chunk boundary (gate layer of 3 x 4800 elements >
/// CHUNK) so the multi-chunk work-queue path itself is pinned
/// bit-identical across thread counts and against the reference.
#[test]
fn parallel_fedavg_matches_reference_across_chunk_boundary() {
    let spec = spec_with_gate(1200, 50);
    let mut rng = fluid::util::prng::Pcg32::new(0xC0FFEE, 1);
    let rand_params = |rng: &mut fluid::util::prng::Pcg32| -> Vec<Tensor> {
        spec.params
            .iter()
            .map(|p| {
                let len: usize = p.shape.iter().product();
                Tensor::from_vec(&p.shape, (0..len).map(|_| rng.uniform(-1.0, 1.0)).collect())
            })
            .collect()
    };
    let global = rand_params(&mut rng);
    let updates: Vec<ClientUpdate> = (0..3)
        .map(|_| {
            let keep: Vec<Vec<bool>> = spec
                .masks
                .iter()
                .map(|m| (0..m.size).map(|_| rng.next_f32() < 0.6).collect())
                .collect();
            ClientUpdate {
                payload: DeltaPayload::DenseF32(rand_params(&mut rng)),
                weight: rng.uniform(0.5, 3.0) as f64,
                mask: MaskSet::from_keep(&spec, &keep),
                staleness: 0,
            }
        })
        .collect();
    let mut scratch = AggScratch::new();
    for mode in [AggregateMode::Plain, AggregateMode::OwnershipWeighted] {
        let want = reference_fedavg(&spec, &global, &updates, mode);
        for threads in [1usize, 2, 8] {
            let got = fedavg_into(&spec, &global, &updates, mode, threads, &mut scratch);
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.shape(), b.shape());
                for (x, y) in a.data().iter().zip(b.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "mode {mode:?} threads {threads}");
                }
            }
            scratch.recycle(got);
        }
    }
}

#[test]
fn prop_partitioners_cover_exactly() {
    check(
        Config { cases: 80, ..Default::default() },
        |g: &mut Gen| {
            let n = g.usize_in(1, 300);
            let k = g.usize_in(1, 12);
            let alpha = g.f32_in(0.1, 5.0) as f64;
            let seed = g.rng.next_u64();
            (n, k, alpha, seed)
        },
        |_| vec![],
        |(n, k, alpha, seed)| {
            let mut rng = fluid::util::prng::Pcg32::new(*seed, 1);
            let labels: Vec<i32> = (0..*n).map(|i| (i % 7) as i32).collect();
            for parts in [
                partition::iid(*n, *k, &mut rng),
                partition::dirichlet(&labels, *k, *alpha, &mut rng),
                partition::by_chunks(*n, *k),
            ] {
                if !partition::is_exact_cover(&parts, *n) {
                    return Err(format!("not an exact cover: n={n} k={k}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_threshold_calibration_reaches_target() {
    check(
        Config { cases: 80, ..Default::default() },
        |g: &mut Gen| {
            let n = g.usize_in(1, 200);
            let scores = g.vec_f32(n, 0.0, 2.0);
            let needed = g.usize_in(0, n);
            (scores, needed)
        },
        |(s, n)| {
            shrink_vec(s)
                .into_iter()
                .filter(|v| !v.is_empty())
                .map(|v| {
                    let nn = (*n).min(v.len());
                    (v, nn)
                })
                .collect()
        },
        |(scores, needed)| {
            let th = threshold::calibrate(scores, 1e-6, *needed, 1.3, 10_000);
            let got = threshold::count_below(scores, th);
            // zero scores can never fall strictly below any threshold that
            // started positive only if all scores are 0 -> count stalls
            let reachable = scores.iter().filter(|&&s| s < f32::INFINITY).count();
            if got < (*needed).min(reachable) && scores.iter().any(|&s| s > 0.0) {
                return Err(format!("needed {needed}, got {got} below th={th}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_snap_rate_is_idempotent_and_closest() {
    check(
        Config { cases: 100, ..Default::default() },
        |g: &mut Gen| {
            let n = g.usize_in(1, 6);
            let mut menu: Vec<f64> = (0..n).map(|_| g.f32_in(0.1, 1.0) as f64).collect();
            menu.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let x = g.f32_in(0.0, 1.2) as f64;
            (menu, x)
        },
        |_| vec![],
        |(menu, x)| {
            let s = snap_rate(*x, menu);
            if !menu.contains(&s) {
                return Err(format!("snapped {s} not in menu"));
            }
            // idempotent
            if snap_rate(s, menu) != s {
                return Err("not idempotent".into());
            }
            // closest
            for &m in menu {
                if (m - x).abs() + 1e-12 < (s - x).abs() {
                    return Err(format!("{m} closer to {x} than {s}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_detection_never_flags_fastest_client() {
    check(
        Config { cases: 80, ..Default::default() },
        |g: &mut Gen| {
            let n = g.usize_in(2, 40);
            let lat = g.vec_f32(n, 1.0, 100.0);
            let frac = g.f32_in(0.05, 0.5) as f64;
            (lat, frac)
        },
        |(l, f)| {
            shrink_vec(l)
                .into_iter()
                .filter(|v| v.len() >= 2)
                .map(|v| (v, *f))
                .collect()
        },
        |(lat, frac)| {
            let lat64: Vec<f64> = lat.iter().map(|&x| x as f64).collect();
            let d = detect_stragglers(&lat64, *frac, 0.02, &[0.5, 0.75, 1.0]);
            let fastest = lat64
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if d.stragglers.contains(&fastest) && lat64.iter().any(|&x| x != lat64[fastest])
            {
                return Err(format!("fastest client {fastest} flagged"));
            }
            // every straggler needs r <= 1
            if d.rates.iter().any(|&r| r > 1.0) {
                return Err("rate > 1".into());
            }
            Ok(())
        },
    );
}

fn ewma_ctl(n: usize, gain: f64, deadband: f64, rate_min: f64) -> RateController {
    RateController::new(
        n,
        AdaptConfig {
            mode: AdaptMode::Ewma,
            gain,
            deadband,
            rate_min,
            ..AdaptConfig::default()
        },
    )
}

/// Controller law: a slower measured arrival never yields a *larger*
/// keep-rate (monotone response, across the deadband edges and both
/// clamps).
#[test]
fn prop_controller_monotone_response() {
    check(
        Config { cases: 300, ..Default::default() },
        |g: &mut Gen| {
            let rate = g.f32_in(0.1, 1.0) as f64;
            let a = g.f32_in(0.05, 3.0) as f64;
            let b = g.f32_in(0.05, 3.0) as f64;
            let gain = g.f32_in(0.1, 1.5) as f64;
            let db = g.f32_in(0.0, 0.2) as f64;
            (rate, a.min(b), a.max(b), gain, db)
        },
        |_| vec![],
        |&(rate, fast, slow, gain, db)| {
            let ctl = ewma_ctl(1, gain, db, 0.1);
            let (ra, rb) = (ctl.step_rate(rate, fast), ctl.step_rate(rate, slow));
            if rb > ra + 1e-12 {
                return Err(format!(
                    "slower miss raised the rate: step({rate}, {fast}) = {ra} < \
                     step({rate}, {slow}) = {rb}"
                ));
            }
            for r in [ra, rb] {
                if !(0.1..=1.0).contains(&r) {
                    return Err(format!("stepped rate {r} escaped [rate_min, 1]"));
                }
            }
            Ok(())
        },
    );
}

/// Deadband stability: a converged straggler fed any sequence of
/// within-band arrivals never changes its assignment (the smoothed miss
/// is a convex combination of within-band values, so jitter inside the
/// band can never trip a step).
#[test]
fn prop_controller_deadband_stability() {
    check(
        Config { cases: 120, ..Default::default() },
        |g: &mut Gen| {
            let s = g.f32_in(1.3, 2.8) as f64;
            let jitters: Vec<f64> = (0..g.usize_in(1, 12))
                .map(|_| g.f32_in(0.0, 1.0) as f64)
                .collect();
            (s, jitters)
        },
        |_| vec![],
        |(s, jitters)| {
            let t = 10.0;
            let mut ctl = ewma_ctl(2, 0.5, 0.05, 0.1);
            ctl.observe(0, t, t, 1.0);
            ctl.observe(1, s * t, s * t, 1.0);
            ctl.recalibrate(&[0, 1], &[], 0.5, 0.02, &[])
                .ok_or("no detection after promotion")?;
            let r = ctl.rate_of(1);
            if r >= 1.0 {
                return Err(format!("speedup {s} was not promoted"));
            }
            // arrivals anywhere inside the band [1-2db, 1]·T_target
            for j in jitters {
                let miss = (0.90 + j * 0.10) * ctl.t_target();
                ctl.observe(1, miss, s * t, r);
                ctl.observe(0, t, t, 1.0);
                ctl.recalibrate(&[0, 1], &[], 0.5, 0.02, &[])
                    .ok_or("detection vanished")?;
                if ctl.rate_of(1) != r {
                    return Err(format!(
                        "within-band arrival {miss:.3} moved the rate {r} -> {}",
                        ctl.rate_of(1)
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Convergence: a constant-load straggler under the A.3-linear latency
/// model settles within one menu step of the paper's 1/speedup, with
/// its arrival inside the controller's band around T_target.
#[test]
fn prop_controller_converges_to_inverse_speedup() {
    check(
        Config { cases: 100, ..Default::default() },
        |g: &mut Gen| {
            let s = g.f32_in(1.3, 3.0) as f64;
            let gain = g.f32_in(0.3, 0.8) as f64;
            (s, gain)
        },
        |_| vec![],
        |&(s, gain)| {
            let t = 10.0;
            let alpha = 0.05;
            let mut ctl = ewma_ctl(2, gain, 0.05, 0.1);
            for _ in 0..60 {
                ctl.observe(0, t, t, 1.0);
                let r = ctl.rate_of(1);
                let lat = s * t * (alpha + (1.0 - alpha) * r);
                ctl.observe(1, lat, s * t, r);
                ctl.recalibrate(&[0, 1], &[], 0.5, 0.02, &[])
                    .ok_or("no detection")?;
            }
            let r = ctl.rate_of(1);
            // within one DEFAULT_RATES menu step (max gap 0.15) of 1/s
            if (r - 1.0 / s).abs() > 0.15 {
                return Err(format!(
                    "speedup {s}: converged rate {r:.3} vs 1/s = {:.3}",
                    1.0 / s
                ));
            }
            let miss = s * (alpha + (1.0 - alpha) * r);
            if !(0.85..=1.05).contains(&miss) {
                return Err(format!(
                    "speedup {s}: steady-state arrival {miss:.3}x T_target"
                ));
            }
            Ok(())
        },
    );
}

fn gen_arrivals(g: &mut Gen) -> Vec<ClientArrival> {
    let n = g.usize_in(1, 30);
    (0..n)
        .map(|client| ClientArrival {
            client,
            at: g.f32_in(0.1, 100.0) as f64,
            full_latency: g.f32_in(0.1, 100.0) as f64,
        })
        .collect()
}

#[test]
fn prop_full_barrier_round_time_is_max_arrival() {
    check(
        Config { cases: 100, ..Default::default() },
        gen_arrivals,
        |_| vec![],
        |arrivals| {
            let r = EventScheduler::resolve(SyncMode::FullBarrier, arrivals, None);
            let max = arrivals.iter().map(|a| a.at).fold(0.0f64, f64::max);
            if r.round_time != max {
                return Err(format!("round_time {} != max arrival {max}", r.round_time));
            }
            if r.on_time.len() != arrivals.len() || !r.late.is_empty() {
                return Err(format!(
                    "full barrier must include everyone: on_time {} late {}",
                    r.on_time.len(),
                    r.late.len()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_deadline_never_aggregates_after_cutoff() {
    check(
        Config { cases: 120, ..Default::default() },
        |g: &mut Gen| {
            let arrivals = gen_arrivals(g);
            let t_target = g.f32_in(0.1, 100.0) as f64;
            let mult = g.f32_in(0.5, 3.0) as f64;
            (arrivals, t_target, mult)
        },
        |_| vec![],
        |(arrivals, t_target, mult)| {
            let cutoff = mult * t_target;
            let r = EventScheduler::resolve(
                SyncMode::Deadline { multiple_of_t_target: *mult },
                arrivals,
                Some(*t_target),
            );
            let at_of = |c: usize| arrivals.iter().find(|a| a.client == c).unwrap().at;
            if arrivals.iter().any(|a| a.at <= cutoff) {
                // the stated invariant: nothing aggregating arrived late
                for &c in &r.on_time {
                    if at_of(c) > cutoff {
                        return Err(format!(
                            "client {c} aggregated at {} after cutoff {cutoff}",
                            at_of(c)
                        ));
                    }
                }
                for a in &r.late {
                    if a.at <= cutoff {
                        return Err(format!("client {} marked late at {}", a.client, a.at));
                    }
                }
                if r.round_time > cutoff + 1e-12 {
                    return Err(format!("round ran past the cutoff: {}", r.round_time));
                }
            } else {
                // degenerate guard: the server waits for exactly the
                // earliest arrival so the round still makes progress
                if r.on_time.len() != 1 {
                    return Err(format!("want 1 fallback arrival, got {}", r.on_time.len()));
                }
                let min = arrivals.iter().map(|a| a.at).fold(f64::INFINITY, f64::min);
                if at_of(r.on_time[0]) != min {
                    return Err("fallback is not the earliest arrival".into());
                }
            }
            // conservation: every arrival is either on time or late
            if r.on_time.len() + r.late.len() != arrivals.len() {
                return Err("arrival lost by the barrier".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_buffered_round_time_is_kth_arrival() {
    check(
        Config { cases: 100, ..Default::default() },
        |g: &mut Gen| {
            let arrivals = gen_arrivals(g);
            let k = g.usize_in(1, arrivals.len() + 4);
            (arrivals, k)
        },
        |_| vec![],
        |(arrivals, k)| {
            let r =
                EventScheduler::resolve(SyncMode::Buffered { k: *k }, arrivals, None);
            let mut times: Vec<f64> = arrivals.iter().map(|a| a.at).collect();
            times.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let k_eff = (*k).clamp(1, arrivals.len());
            if r.round_time != times[k_eff - 1] {
                return Err(format!(
                    "round_time {} != {}-th arrival {}",
                    r.round_time,
                    k_eff,
                    times[k_eff - 1]
                ));
            }
            if r.on_time.len() != k_eff {
                return Err(format!("on_time {} != k_eff {k_eff}", r.on_time.len()));
            }
            // nobody on time arrived after anyone late
            let latest_on = r
                .on_time
                .iter()
                .map(|&c| arrivals.iter().find(|a| a.client == c).unwrap().at)
                .fold(0.0f64, f64::max);
            if r.late.iter().any(|a| a.at < latest_on) {
                return Err("late arrival earlier than an on-time one".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_round_trip() {
    check(
        Config { cases: 100, ..Default::default() },
        |g: &mut Gen| random_json(g, 3),
        |_| vec![],
        |j| {
            let text = j.to_string_pretty();
            let back = jsonlite::parse(&text).map_err(|e| e.to_string())?;
            if &back != j {
                return Err(format!("round trip mismatch: {j:?} vs {back:?}"));
            }
            let compact = j.to_string_compact();
            let back2 = jsonlite::parse(&compact).map_err(|e| e.to_string())?;
            if &back2 != j {
                return Err("compact round trip mismatch".into());
            }
            Ok(())
        },
    );
}

fn random_json(g: &mut Gen, depth: usize) -> Json {
    let pick = g.usize_in(0, if depth == 0 { 3 } else { 5 });
    match pick {
        0 => Json::Null,
        1 => Json::Bool(g.bool()),
        // grid-aligned numbers avoid float-text round-trip fuzz
        2 => Json::Num((g.usize_in(0, 1_000_000) as f64) / 64.0),
        3 => {
            let n = g.usize_in(0, 8);
            let s: String = (0..n)
                .map(|_| {
                    let c = g.usize_in(32, 126) as u8 as char;
                    c
                })
                .collect();
            Json::Str(s)
        }
        4 => {
            let n = g.usize_in(0, 4);
            Json::Arr((0..n).map(|_| random_json(g, depth - 1)).collect())
        }
        _ => {
            let n = g.usize_in(0, 4);
            let mut m = std::collections::BTreeMap::new();
            for i in 0..n {
                m.insert(format!("k{i}"), random_json(g, depth - 1));
            }
            Json::Obj(m)
        }
    }
}

/// Snapshot codec property: encode → decode → encode is a byte-identical
/// fixpoint over randomized engine states — tensors with arbitrary
/// shapes and bit patterns (NaN test metrics included), every policy
/// state variant, detection tables, stale buffers, and round histories.
#[test]
fn prop_snapshot_codec_round_trips() {
    use fluid::coordinator::RoundRecord;
    use fluid::snapshot::{PolicyState, Snapshot, StaleEntry};
    use fluid::straggler::Detection;

    fn random_tensor(g: &mut Gen) -> Tensor {
        let rank = g.usize_in(1, 3);
        let shape: Vec<usize> = (0..rank).map(|_| g.usize_in(1, 6)).collect();
        let n: usize = shape.iter().product();
        // raw bit patterns, not just nice floats
        let data: Vec<f32> = (0..n).map(|_| f32::from_bits(g.rng.next_u32())).collect();
        Tensor::from_vec(&shape, data)
    }

    fn random_record(g: &mut Gen, round: usize) -> RoundRecord {
        RoundRecord {
            round,
            round_time: g.rng.next_f64() * 10.0,
            vtime: g.rng.next_f64() * 100.0,
            cohort: (0..g.usize_in(0, 8)).map(|_| g.usize_in(0, 99)).collect(),
            straggler_ids: (0..g.usize_in(0, 3)).map(|_| g.usize_in(0, 99)).collect(),
            straggler_rates: (0..g.usize_in(0, 3)).map(|_| g.rng.next_f64()).collect(),
            t_target: g.rng.next_f64(),
            straggler_time: g.rng.next_f64(),
            train_loss: g.rng.next_f64(),
            train_acc: g.rng.next_f64(),
            test_loss: if g.bool() { f64::NAN } else { g.rng.next_f64() },
            test_acc: if g.bool() { f64::NAN } else { g.rng.next_f64() },
            invariant_fraction: g.rng.next_f64(),
            calibration_secs: g.rng.next_f64(),
            aggregated: g.usize_in(0, 64),
            dropped_updates: g.usize_in(0, 8),
            stale_folded: g.usize_in(0, 8),
            update_bytes: g.usize_in(0, 1 << 24),
            vanished: g.usize_in(0, 8),
            quarantined: g.usize_in(0, 8),
            shard_retries: g.usize_in(0, 4),
            quorum_fraction: g.rng.next_f64(),
            straggler_wait: g.rng.next_f64() * 10.0,
            admitted_stale: g.usize_in(0, 8),
            soft_fraction: g.rng.next_f64(),
        }
    }

    check(
        Config { cases: 60, ..Default::default() },
        |g: &mut Gen| {
            let n = g.usize_in(1, 40);
            let rounds = g.usize_in(0, 6);
            let policy = match g.usize_in(0, 2) {
                0 => PolicyState::Stateless,
                1 => PolicyState::Random {
                    state: g.rng.next_u64(),
                    inc: g.rng.next_u64() | 1,
                },
                _ => {
                    let groups = g.usize_in(1, 3);
                    PolicyState::Invariant {
                        th: (0..groups).map(|_| g.f32_in(0.0, 1.0)).collect(),
                        streak: (0..groups)
                            .map(|_| (0..g.usize_in(1, 8)).map(|_| g.rng.next_u32() % 10).collect())
                            .collect(),
                        score: (0..groups)
                            .map(|_| (0..g.usize_in(1, 8)).map(|_| g.f32_in(0.0, 1.0)).collect())
                            .collect(),
                        observations: g.usize_in(0, 50),
                    }
                }
            };
            let detection = if g.bool() {
                let k = g.usize_in(0, 4);
                Some(Detection {
                    stragglers: (0..k).map(|_| g.usize_in(0, n - 1)).collect(),
                    t_target: g.rng.next_f64() * 10.0,
                    speedups: (0..k).map(|_| 1.0 + g.rng.next_f64()).collect(),
                    rates: (0..k).map(|_| g.rng.next_f64()).collect(),
                })
            } else {
                None
            };
            // the CTRL section: arbitrary f64 bit patterns (NaN/inf
            // included) must round-trip exactly
            let ctrl = if g.bool() {
                Some(fluid::straggler::CtrlState {
                    profile: (0..n).map(|_| f64::from_bits(g.rng.next_u64())).collect(),
                    measured: (0..n).map(|_| f64::from_bits(g.rng.next_u64())).collect(),
                    rates: (0..n).map(|_| g.rng.next_f64()).collect(),
                    t_target: g.rng.next_f64() * 10.0,
                })
            } else {
                None
            };
            let stale: Vec<StaleEntry> = (0..g.usize_in(0, 2))
                .map(|_| StaleEntry {
                    client: g.usize_in(0, n - 1),
                    params: (0..g.usize_in(1, 3)).map(|_| random_tensor(g)).collect(),
                    weight: g.rng.next_f64() * 60.0,
                    mean_loss: g.rng.next_f64(),
                    mean_acc: g.rng.next_f64(),
                    steps: g.usize_in(0, 8),
                    mask: (0..g.usize_in(1, 2)).map(|_| random_tensor(g)).collect(),
                    arrives_at: g.rng.next_f64() * 100.0,
                    born_round: g.usize_in(0, 100),
                })
                .collect();
            Snapshot {
                fingerprint: format!("prop|n={n}|x={}", g.rng.next_u64()),
                next_round: rounds,
                vtime: g.rng.next_f64() * 1000.0,
                calib_total: g.rng.next_f64(),
                train_wall: g.rng.next_f64() * 10.0,
                params: (0..g.usize_in(1, 4)).map(|_| random_tensor(g)).collect(),
                policy,
                availability: (0..n).map(|_| g.bool()).collect(),
                detection,
                ctrl,
                last_latencies: (0..n).map(|_| g.rng.next_f64() * 10.0).collect(),
                last_full_latencies: (0..n).map(|_| g.rng.next_f64() * 10.0).collect(),
                free_at: (0..n).map(|_| g.rng.next_f64() * 10.0).collect(),
                stale,
                // q8 residual state: arbitrary bit patterns must survive
                resid: (0..g.usize_in(0, 3))
                    .map(|c| {
                        (
                            c as u64 * 7 + g.rng.next_u64() % 100,
                            (0..g.usize_in(1, 3))
                                .map(|_| {
                                    (0..g.usize_in(0, 6))
                                        .map(|_| f32::from_bits(g.rng.next_u32()))
                                        .collect()
                                })
                                .collect(),
                        )
                    })
                    .collect(),
                quarantine: (0..g.usize_in(0, 3))
                    .map(|c| fluid::engine::QuarEntry {
                        client: c * 3 + g.usize_in(0, 2),
                        strikes: 1 + g.rng.next_u32() % 6,
                        barred_until: g.usize_in(0, 200),
                        last_strike: g.usize_in(0, 100),
                    })
                    .collect(),
                // the optional ZOO section: absent, per-client versions
                // (safa), and per-client fractions (helios) all round-trip
                zoo: match g.usize_in(0, 2) {
                    0 => None,
                    1 => Some(fluid::snapshot::ZooState::Safa {
                        version: (0..n).map(|_| g.usize_in(0, 200)).collect(),
                    }),
                    _ => Some(fluid::snapshot::ZooState::Helios {
                        frac: (0..n).map(|_| g.rng.next_f64()).collect(),
                    }),
                },
                records: (0..rounds).map(|r| random_record(g, r)).collect(),
            }
        },
        |_| vec![],
        |snap| {
            let bytes = snap.encode();
            let back = Snapshot::decode(&bytes).map_err(|e| format!("decode: {e:#}"))?;
            let again = back.encode();
            if again != bytes {
                return Err(format!(
                    "encode/decode/encode not a fixpoint ({} vs {} bytes)",
                    again.len(),
                    bytes.len()
                ));
            }
            if back.next_round != snap.next_round
                || back.records.len() != snap.records.len()
                || back.availability != snap.availability
                || back.fingerprint != snap.fingerprint
            {
                return Err("decoded fields drifted from the original".into());
            }
            // a destroyed byte anywhere must never decode successfully
            let mut bad = bytes.clone();
            let idx = (snap.next_round * 131 + bad.len() / 3) % bad.len();
            bad[idx] ^= 0xFF;
            if Snapshot::decode(&bad).is_ok() {
                return Err(format!("decode accepted a corrupted byte at {idx}"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// shard wire framing (DESIGN.md §11)
// ---------------------------------------------------------------------

fn rand_wire_tensor(rng: &mut fluid::util::prng::Pcg32) -> Tensor {
    let rank = 1 + (rng.next_u32() as usize) % 3;
    let shape: Vec<usize> = (0..rank).map(|_| 1 + (rng.next_u32() as usize) % 5).collect();
    let n: usize = shape.iter().product();
    // raw IEEE-754 bit patterns: NaNs, infinities, denormals and all
    let data: Vec<f32> = (0..n).map(|_| f32::from_bits(rng.next_u32())).collect();
    Tensor::from_vec(&shape, data)
}

/// A randomized shard message of the given kind, derived entirely from
/// the seed so cases replay and shrink deterministically.
fn rand_wire_message(kind: usize, nitems: usize, seed: u64) -> fluid::engine::wire::ShardMessage {
    use fluid::engine::wire::ShardMessage;
    use fluid::fl::LocalResult;
    let mut rng = fluid::util::prng::Pcg32::new(seed, 91);
    let shard = (rng.next_u32() as usize) % 16;
    let round = (rng.next_u32() as usize) % 1000;
    let base = (rng.next_u32() as usize) % 5000;
    match kind {
        0 => ShardMessage::Results {
            shard,
            round,
            base,
            items: (0..nitems)
                .map(|i| {
                    if rng.next_f32() < 0.75 {
                        let np = 1 + (rng.next_u32() as usize) % 3;
                        Ok(LocalResult {
                            params: (0..np).map(|_| rand_wire_tensor(&mut rng)).collect(),
                            mean_loss: f64::from_bits(rng.next_u64()),
                            mean_acc: f64::from_bits(rng.next_u64()),
                            steps: (rng.next_u32() as usize) % 100,
                            weight: f64::from_bits(rng.next_u64()),
                        })
                    } else {
                        Err(format!("client {i} failed: code {}", rng.next_u32()))
                    }
                })
                .collect(),
        },
        1 => ShardMessage::Deltas {
            shard,
            base,
            items: (0..nitems)
                .map(|i| {
                    if rng.next_f32() < 0.75 {
                        let nt = (rng.next_u32() as usize) % 3;
                        Ok((0..nt).map(|_| rand_wire_tensor(&mut rng)).collect())
                    } else {
                        Err(format!("voter {i} timed out after {}ms", rng.next_u32() % 10_000))
                    }
                })
                .collect(),
        },
        2 => ShardMessage::Fault { shard, round },
        _ => ShardMessage::Packed {
            shard,
            round,
            base,
            items: (0..nitems)
                .map(|i| {
                    if rng.next_f32() < 0.75 {
                        let np = 1 + (rng.next_u32() as usize) % 3;
                        let payload = match rng.next_u32() % 3 {
                            0 => DeltaPayload::DenseF32(
                                (0..np).map(|_| rand_wire_tensor(&mut rng)).collect(),
                            ),
                            1 => DeltaPayload::SparseF32(SparseUpdate {
                                values: (0..np)
                                    .map(|_| {
                                        let n = (rng.next_u32() as usize) % 9;
                                        (0..n)
                                            .map(|_| f32::from_bits(rng.next_u32()))
                                            .collect()
                                    })
                                    .collect(),
                            }),
                            _ => DeltaPayload::SparseQ8(QuantUpdate {
                                scales: (0..np)
                                    .map(|_| f32::from_bits(rng.next_u32()))
                                    .collect(),
                                values: (0..np)
                                    .map(|_| {
                                        let n = (rng.next_u32() as usize) % 9;
                                        (0..n).map(|_| rng.next_u32() as i8).collect()
                                    })
                                    .collect(),
                            }),
                        };
                        Ok(PackedResult {
                            payload,
                            mean_loss: f64::from_bits(rng.next_u64()),
                            mean_acc: f64::from_bits(rng.next_u64()),
                            steps: (rng.next_u32() as usize) % 100,
                            weight: f64::from_bits(rng.next_u64()),
                        })
                    } else {
                        Err(format!("client {i} failed: code {}", rng.next_u32()))
                    }
                })
                .collect(),
        },
    }
}

/// Wire fixpoint: for every message kind, encode → decode → encode is
/// byte-for-byte identical — floats travel as raw bit patterns and
/// errors as plain strings, so nothing is lost or renormalized.
#[test]
fn prop_wire_message_encode_decode_is_a_byte_fixpoint() {
    use fluid::engine::wire::{decode_message, encode_message};
    let scratch = std::cell::RefCell::new(AggScratch::new());
    check(
        Config { cases: 60, ..Default::default() },
        |g: &mut Gen| {
            let kind = g.usize_in(0, 3);
            let nitems = g.usize_in(0, 6);
            let seed = g.rng.next_u64();
            (kind, nitems, seed)
        },
        |_| vec![],
        |&(kind, nitems, seed)| {
            let msg = rand_wire_message(kind, nitems, seed);
            let (mut blob, mut frame) = (Vec::new(), Vec::new());
            encode_message(&msg, &mut blob, &mut frame);
            let mut s = scratch.borrow_mut();
            let decoded = decode_message(&frame, &mut s)
                .map_err(|e| format!("decode failed: {e:#}"))?;
            let (mut blob2, mut frame2) = (Vec::new(), Vec::new());
            encode_message(&decoded, &mut blob2, &mut frame2);
            if frame != frame2 {
                return Err(format!(
                    "kind {kind}: re-encode drifted ({} vs {} bytes)",
                    frame.len(),
                    frame2.len()
                ));
            }
            Ok(())
        },
    );
}

/// Robustness: any single corrupted byte and any truncation of a valid
/// frame yields a clean `Err` from `decode_message` — never a panic,
/// never a silently-wrong message.
#[test]
fn prop_wire_corruption_and_truncation_error_cleanly() {
    use fluid::engine::wire::{decode_message, encode_message};
    let scratch = std::cell::RefCell::new(AggScratch::new());
    check(
        Config { cases: 80, ..Default::default() },
        |g: &mut Gen| {
            let kind = g.usize_in(0, 3);
            let nitems = g.usize_in(0, 5);
            let seed = g.rng.next_u64();
            let flip_at = g.rng.next_u64();
            let flip_with = g.usize_in(1, 255) as u8;
            let cut_at = g.rng.next_u64();
            (kind, nitems, seed, flip_at, flip_with, cut_at)
        },
        |_| vec![],
        |&(kind, nitems, seed, flip_at, flip_with, cut_at)| {
            let msg = rand_wire_message(kind, nitems, seed);
            let (mut blob, mut frame) = (Vec::new(), Vec::new());
            encode_message(&msg, &mut blob, &mut frame);
            let mut s = scratch.borrow_mut();

            let pos = (flip_at % frame.len() as u64) as usize;
            let mut bad = frame.clone();
            bad[pos] ^= flip_with;
            if decode_message(&bad, &mut s).is_ok() {
                return Err(format!("flip {flip_with:#04x} at byte {pos} decoded fine"));
            }

            let cut = (cut_at % frame.len() as u64) as usize;
            if decode_message(&frame[..cut], &mut s).is_ok() {
                return Err(format!("truncation to {cut} bytes decoded fine"));
            }
            Ok(())
        },
    );
}

/// The root-fold contract behind the sharded executor: split a cohort's
/// updates into arbitrary contiguous shard slices, ship each slice
/// through the wire codec, fold the slices back with `tree_reduce`'s
/// ordered concatenation at an arbitrary thread count, and aggregate —
/// the result is bit-identical to the serial scalar `fedavg` reference
/// on the original updates, for every shard split and both modes.
#[test]
fn prop_sharded_wire_fold_matches_serial_fedavg() {
    use fluid::engine::wire::{decode_message, encode_message, ShardMessage};
    use fluid::fl::parallel::tree_reduce;
    use fluid::fl::LocalResult;
    let scratch = std::cell::RefCell::new(AggScratch::new());
    check(
        Config { cases: 32, ..Default::default() },
        |g: &mut Gen| {
            let n0 = g.usize_in(1, 5);
            let n1 = g.usize_in(1, 10);
            let nclients = g.usize_in(1, 8);
            let shards = g.usize_in(1, 6);
            let threads = g.usize_in(1, 4);
            let seed = g.rng.next_u64();
            (n0, n1, nclients, shards, threads, seed)
        },
        |_| vec![],
        |&(n0, n1, nclients, shards, threads, seed)| {
            let spec = spec_with_gate(n0, n1);
            let mut rng = fluid::util::prng::Pcg32::new(seed, 17);
            let rand_params = |rng: &mut fluid::util::prng::Pcg32| -> Vec<Tensor> {
                spec.params
                    .iter()
                    .map(|p| {
                        let len: usize = p.shape.iter().product();
                        Tensor::from_vec(
                            &p.shape,
                            (0..len).map(|_| rng.uniform(-2.0, 2.0)).collect(),
                        )
                    })
                    .collect()
            };
            let global = rand_params(&mut rng);
            let updates: Vec<ClientUpdate> = (0..nclients)
                .map(|_| {
                    let keep: Vec<Vec<bool>> = spec
                        .masks
                        .iter()
                        .map(|m| (0..m.size).map(|_| rng.next_f32() < 0.7).collect())
                        .collect();
                    ClientUpdate {
                        payload: DeltaPayload::DenseF32(rand_params(&mut rng)),
                        weight: rng.uniform(0.1, 5.0) as f64,
                        mask: MaskSet::from_keep(&spec, &keep),
                        staleness: (rng.next_u32() % 3) as usize,
                    }
                })
                .collect();

            // each shard encodes its contiguous slice as a wire message
            let bounds = |s: usize| (s * nclients / shards, (s + 1) * nclients / shards);
            let mut frames = Vec::with_capacity(shards);
            for s in 0..shards {
                let (lo, hi) = bounds(s);
                let items: Vec<Result<LocalResult, String>> = updates[lo..hi]
                    .iter()
                    .map(|u| {
                        Ok(LocalResult {
                            params: u.dense_params().to_vec(),
                            mean_loss: 0.0,
                            mean_acc: 0.0,
                            steps: 1,
                            weight: u.weight,
                        })
                    })
                    .collect();
                let msg = ShardMessage::Results { shard: s, round: 0, base: lo, items };
                let (mut blob, mut frame) = (Vec::new(), Vec::new());
                encode_message(&msg, &mut blob, &mut frame);
                frames.push(frame);
            }

            // decode every slice, then fold through the fixed pairwise
            // tree exactly as the sharded root does
            let mut slices = Vec::with_capacity(shards);
            for (s, frame) in frames.iter().enumerate() {
                let mut sc = scratch.borrow_mut();
                match decode_message(frame, &mut sc).map_err(|e| format!("{e:#}"))? {
                    ShardMessage::Results { base, items, .. } => {
                        if base != bounds(s).0 {
                            return Err(format!("shard {s}: base {base} drifted"));
                        }
                        let res: Result<Vec<LocalResult>, String> = items.into_iter().collect();
                        slices.push((base, res?));
                    }
                    other => return Err(format!("shard {s} decoded as {other:?}")),
                }
            }
            let folded = tree_reduce(
                shards,
                1,
                threads,
                |s, _| vec![slices[s].clone()],
                |mut a, mut b| {
                    a.append(&mut b);
                    a
                },
            )
            .ok_or("tree_reduce returned None for a non-empty fold")?;
            let mut rebuilt_results = Vec::with_capacity(nclients);
            for (base, items) in folded {
                if base != rebuilt_results.len() {
                    return Err(format!(
                        "fold order broken: slice base {base} at position {}",
                        rebuilt_results.len()
                    ));
                }
                rebuilt_results.extend(items);
            }
            if rebuilt_results.len() != nclients {
                return Err(format!("fold produced {} of {nclients}", rebuilt_results.len()));
            }

            // aggregate the wire-rebuilt updates; compare bit-for-bit
            // against the serial reference on the originals
            let rebuilt: Vec<ClientUpdate> = rebuilt_results
                .into_iter()
                .zip(&updates)
                .map(|(res, u)| ClientUpdate {
                    payload: DeltaPayload::DenseF32(res.params),
                    weight: res.weight,
                    mask: u.mask.clone(),
                    staleness: u.staleness,
                })
                .collect();
            for mode in [AggregateMode::Plain, AggregateMode::OwnershipWeighted] {
                let want = reference_fedavg(&spec, &global, &updates, mode);
                let mut s = scratch.borrow_mut();
                let got = fedavg_into(&spec, &global, &rebuilt, mode, threads, &mut s);
                for (pi, (a, b)) in got.iter().zip(&want).enumerate() {
                    for (j, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
                        if x.to_bits() != y.to_bits() {
                            return Err(format!(
                                "shards={shards} mode {mode:?} param {pi} elem {j}: \
                                 {x} vs {y} after the wire fold"
                            ));
                        }
                    }
                }
                s.recycle(got);
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// update codec: payloads, framing, quantization (DESIGN.md §12)
// ---------------------------------------------------------------------

/// The same name→group mapping the aggregator and codec use, rebuilt
/// from public spec APIs so the tests don't lean on crate internals.
fn group_span_of(spec: &ModelSpec, p_idx: usize) -> Option<(usize, usize)> {
    let p = &spec.params[p_idx];
    let prefix: &str = p.name.rsplit_once('_').map(|(a, _)| a).unwrap_or(&p.name);
    let g = spec.mask_index(prefix)?;
    let n = spec.masks[g].size;
    let cols = *p.shape.last()?;
    if cols == n {
        Some((g, 1))
    } else if cols == 4 * n {
        Some((g, 4))
    } else {
        None
    }
}

/// Random client params that honor the dropout invariant: dropped
/// columns bit-equal the broadcast global, kept columns (and non-group
/// params) perturbed.
fn invariant_client_params(
    spec: &ModelSpec,
    global: &[Tensor],
    mask: &MaskSet,
    rng: &mut fluid::util::prng::Pcg32,
) -> Vec<Tensor> {
    global
        .iter()
        .enumerate()
        .map(|(pi, t)| {
            let mut q = t.clone();
            let cols = *spec.params[pi].shape.last().unwrap_or(&1);
            match group_span_of(spec, pi) {
                Some((g, span)) => {
                    let n = spec.masks[g].size;
                    for (e, v) in q.data_mut().iter_mut().enumerate() {
                        let col = e % cols;
                        let neuron = if span == 1 { col } else { col % n };
                        if mask.is_kept(g, neuron) {
                            *v += rng.uniform(-1.0, 1.0);
                        }
                    }
                }
                None => {
                    for v in q.data_mut() {
                        *v += rng.uniform(-1.0, 1.0);
                    }
                }
            }
            q
        })
        .collect()
}

fn rand_codec_setup(
    n0: usize,
    n1: usize,
    seed: u64,
) -> (ModelSpec, Vec<Tensor>, MaskSet, Vec<Tensor>) {
    let spec = spec_with_gate(n0, n1);
    let mut rng = fluid::util::prng::Pcg32::new(seed, 29);
    let global: Vec<Tensor> = spec
        .params
        .iter()
        .map(|p| {
            let len: usize = p.shape.iter().product();
            Tensor::from_vec(&p.shape, (0..len).map(|_| rng.uniform(-1.0, 1.0)).collect())
        })
        .collect();
    let keep: Vec<Vec<bool>> = spec
        .masks
        .iter()
        .map(|m| (0..m.size).map(|_| rng.next_f32() < 0.6).collect())
        .collect();
    let mask = MaskSet::from_keep(&spec, &keep);
    let params = invariant_client_params(&spec, &global, &mask, &mut rng);
    (spec, global, mask, params)
}

#[test]
fn prop_payload_framing_is_a_byte_fixpoint_and_truncation_errs() {
    // encode -> frame -> decode -> re-frame lands on the identical byte
    // string for all three representations, wire_bytes() predicts the
    // frame length exactly, and every strict prefix of a frame decodes
    // to a clean Err (never a panic, never a silent partial payload)
    use fluid::fl::codec::{put_payload, take_payload};
    use fluid::snapshot::{Reader, Writer};
    check(
        Config { cases: 30, ..Default::default() },
        |g: &mut Gen| {
            let n0 = g.usize_in(1, 5);
            let n1 = g.usize_in(1, 8);
            let mode = g.usize_in(0, 2);
            let seed = g.rng.next_u64();
            (n0, n1, mode, seed)
        },
        |_| vec![],
        |&(n0, n1, mode, seed)| {
            let (spec, global, mask, params) = rand_codec_setup(n0, n1, seed);
            let mode = match mode {
                0 => Compression::Dense,
                1 => Compression::Sparse,
                _ => Compression::Q8,
            };
            let mut codec = Codec::new(mode);
            let mut s = AggScratch::new();
            let payload = codec.encode(7, params, &mask, &global, &spec, &mut s);
            let mut w = Writer::new();
            put_payload(&mut w, &payload);
            let bytes = w.into_bytes();
            if bytes.len() != payload.wire_bytes() {
                return Err(format!(
                    "{mode:?}: wire_bytes promises {} but the framing wrote {}",
                    payload.wire_bytes(),
                    bytes.len()
                ));
            }
            let decoded = take_payload(&mut Reader::new(&bytes), &mut s)
                .map_err(|e| format!("{mode:?}: decode failed: {e:#}"))?;
            let mut w2 = Writer::new();
            put_payload(&mut w2, &decoded);
            if w2.into_bytes() != bytes {
                return Err(format!("{mode:?}: encode -> decode -> re-encode drifted"));
            }
            for cut in 0..bytes.len() {
                if take_payload(&mut Reader::new(&bytes[..cut]), &mut s).is_ok() {
                    return Err(format!(
                        "{mode:?}: frame truncated to {cut}/{} bytes decoded fine",
                        bytes.len()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dense_and_sparse_payloads_round_trip_bit_exactly() {
    // DenseF32 must carry the client's tensors untouched (it is the
    // determinism reference), and the sparse packing must reconstruct
    // them bit for bit wherever the invariant holds — dropped columns
    // come back as the broadcast global, which is exactly what the
    // client was sent
    check(
        Config { cases: 30, ..Default::default() },
        |g: &mut Gen| {
            let n0 = g.usize_in(1, 5);
            let n1 = g.usize_in(1, 8);
            let sparse = g.bool();
            let seed = g.rng.next_u64();
            (n0, n1, sparse, seed)
        },
        |_| vec![],
        |&(n0, n1, sparse, seed)| {
            let (spec, global, mask, params) = rand_codec_setup(n0, n1, seed);
            let mode = if sparse { Compression::Sparse } else { Compression::Dense };
            let mut codec = Codec::new(mode);
            let mut s = AggScratch::new();
            let payload = codec.encode(3, params.clone(), &mask, &global, &spec, &mut s);
            if let DeltaPayload::DenseF32(ts) = &payload {
                for (pi, (a, b)) in ts.iter().zip(&params).enumerate() {
                    for (e, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
                        if x.to_bits() != y.to_bits() {
                            return Err(format!("dense payload mutated param {pi} elem {e}"));
                        }
                    }
                }
            } else if !sparse {
                return Err(format!("dense mode produced {:?}", payload.mode()));
            }
            let back = unpack(payload, &mask, &global, &spec, &mut s)
                .map_err(|e| format!("unpack: {e:#}"))?;
            for (pi, (a, b)) in back.iter().zip(&params).enumerate() {
                if a.shape() != b.shape() {
                    return Err(format!("param {pi}: shape {:?} vs {:?}", a.shape(), b.shape()));
                }
                for (e, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
                    if x.to_bits() != y.to_bits() {
                        return Err(format!(
                            "{mode:?} param {pi} elem {e}: {x} vs {y} after round trip"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_q8_dequantize_error_bounded_by_half_scale() {
    // per element of the packed set: |dequantized - true| <= scale/2
    // (plus f32 rounding headroom); dropped columns reconstruct the
    // broadcast global bit-exactly
    check(
        Config { cases: 30, ..Default::default() },
        |g: &mut Gen| {
            let n0 = g.usize_in(1, 5);
            let n1 = g.usize_in(1, 8);
            let seed = g.rng.next_u64();
            (n0, n1, seed)
        },
        |_| vec![],
        |&(n0, n1, seed)| {
            let (spec, global, mask, params) = rand_codec_setup(n0, n1, seed);
            let mut codec = Codec::new(Compression::Q8);
            let mut s = AggScratch::new();
            let payload = codec.encode(1, params.clone(), &mask, &global, &spec, &mut s);
            let scales: Vec<f32> = match &payload {
                DeltaPayload::SparseQ8(q) => q.scales.clone(),
                other => return Err(format!("q8 encode produced {:?}", other.mode())),
            };
            let back = unpack(payload, &mask, &global, &spec, &mut s)
                .map_err(|e| format!("unpack: {e:#}"))?;
            for (pi, (a, b)) in back.iter().zip(&params).enumerate() {
                let cols = *spec.params[pi].shape.last().unwrap_or(&1);
                let bound = scales[pi] as f64 * 0.5001 + 1e-6;
                for (e, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
                    let packed = match group_span_of(&spec, pi) {
                        Some((g, span)) => {
                            let n = spec.masks[g].size;
                            let col = e % cols;
                            mask.is_kept(g, if span == 1 { col } else { col % n })
                        }
                        None => true,
                    };
                    if packed {
                        let err = (*x as f64 - *y as f64).abs();
                        if err > bound {
                            return Err(format!(
                                "param {pi} elem {e}: |{x} - {y}| = {err} > {bound} \
                                 (scale {})",
                                scales[pi]
                            ));
                        }
                    } else if x.to_bits() != global[pi].data()[e].to_bits() {
                        return Err(format!(
                            "param {pi} elem {e}: dropped column {x} is not the global"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn q8_error_feedback_telescopes_to_the_exact_dense_sum() {
    // Deliberately exact-arithmetic construction: element 0 of every
    // tensor carries a delta of 15.875 = 127 x 0.125 each round (zero
    // residual, pins the symmetric scale at exactly 1/8), every other
    // delta is a multiple of scale/2 = 0.0625 in [-1, 1]. All the f32
    // operations below are then exact, so the telescoped identity
    //   sum(dequantized) + final residual == sum(true deltas)
    // must hold BITWISE over repeated rounds — error feedback loses
    // nothing, it only defers.
    let spec = spec_with_groups(&[4]);
    let global: Vec<Tensor> = spec.params.iter().map(|p| Tensor::zeros(&p.shape)).collect();
    let mask = MaskSet::full(&spec);
    let mut codec = Codec::new(Compression::Q8);
    let mut s = AggScratch::new();
    let mut true_sum: Vec<Vec<f32>> = global.iter().map(|t| vec![0.0; t.len()]).collect();
    let mut deq_sum: Vec<Vec<f32>> = global.iter().map(|t| vec![0.0; t.len()]).collect();
    for r in 0..6usize {
        let params: Vec<Tensor> = global
            .iter()
            .enumerate()
            .map(|(pi, t)| {
                let data: Vec<f32> = (0..t.len())
                    .map(|e| {
                        if e == 0 {
                            15.875
                        } else {
                            (((r * 7 + pi * 5 + e) % 33) as f32 - 16.0) * 0.0625
                        }
                    })
                    .collect();
                Tensor::from_vec(t.shape(), data)
            })
            .collect();
        for (pi, t) in params.iter().enumerate() {
            for (e, v) in t.data().iter().enumerate() {
                true_sum[pi][e] += v;
            }
        }
        let payload = codec.encode(9, params, &mask, &global, &spec, &mut s);
        if let DeltaPayload::SparseQ8(q) = &payload {
            for (pi, sc) in q.scales.iter().enumerate() {
                assert_eq!(
                    sc.to_bits(),
                    0.125f32.to_bits(),
                    "round {r} param {pi}: scale {sc} drifted off the pinned 1/8"
                );
            }
        } else {
            panic!("q8 encode produced {:?}", payload.mode());
        }
        let back = unpack(payload, &mask, &global, &spec, &mut s).unwrap();
        for (pi, t) in back.iter().enumerate() {
            for (e, v) in t.data().iter().enumerate() {
                deq_sum[pi][e] += v;
            }
        }
    }
    let resid = codec.export_resid();
    assert_eq!(resid.len(), 1, "one client encoded, one residual set");
    let (client, per_param) = &resid[0];
    assert_eq!(*client, 9);
    for (pi, rp) in per_param.iter().enumerate() {
        for (e, (&deq, &truth)) in deq_sum[pi].iter().zip(&true_sum[pi]).enumerate() {
            assert!(
                rp[e].abs() <= 0.0625,
                "param {pi} elem {e}: residual {} beyond scale/2",
                rp[e]
            );
            let got = deq + rp[e];
            assert_eq!(
                got.to_bits(),
                truth.to_bits(),
                "param {pi} elem {e}: dequantized {deq} + residual {} = {got} != {truth}",
                rp[e]
            );
        }
    }
}

// ---------------------------------------------------------------------
// chaos plane: validator and quarantine laws (DESIGN.md §13)
// ---------------------------------------------------------------------

/// Validator law: admission is *exactly* the spec — a finite update
/// whose relative-L2 ratio sits within the bound is never quarantined,
/// one beyond the bound always is, and a single non-finite value or a
/// dropped tensor flips the verdict regardless of norms. False
/// quarantines would silently starve honest clients, so the clean
/// direction is the load-bearing half.
#[test]
fn prop_validator_admits_exactly_the_in_bound_finite_updates() {
    use fluid::engine::chaos::Violation;
    use fluid::engine::UpdateValidator;
    use fluid::fl::LocalResult;
    check(
        Config { cases: 80, ..Default::default() },
        |g: &mut Gen| {
            let ntensors = g.usize_in(1, 4);
            let shapes: Vec<Vec<usize>> = (0..ntensors)
                .map(|_| (0..g.usize_in(1, 2)).map(|_| g.usize_in(1, 12)).collect())
                .collect();
            let seed = g.rng.next_u64();
            // straddles typical relative-L2 ratios of the cases below,
            // so both verdicts are exercised
            let bound = g.f32_in(0.0, 2.0) as f64;
            (shapes, seed, bound)
        },
        |_| vec![],
        |(shapes, seed, bound)| {
            let mut rng = fluid::util::prng::Pcg32::new(*seed, 43);
            let broadcast: Vec<Tensor> = shapes
                .iter()
                .map(|s| {
                    let n: usize = s.iter().product();
                    Tensor::from_vec(s, (0..n).map(|_| rng.uniform(-2.0, 2.0)).collect())
                })
                .collect();
            let params: Vec<Tensor> = broadcast
                .iter()
                .map(|t| {
                    let data: Vec<f32> =
                        t.data().iter().map(|v| v + rng.uniform(-1.0, 1.0)).collect();
                    Tensor::from_vec(t.shape(), data)
                })
                .collect();
            // the spec'd ratio, replicated with the validator's exact
            // accumulation order so the comparison is bit-honest
            let (mut diff2, mut base2) = (0.0f64, 0.0f64);
            for (u, b) in params.iter().zip(&broadcast) {
                for (&x, &y) in u.data().iter().zip(b.data()) {
                    let d = (x - y) as f64;
                    diff2 += d * d;
                    base2 += (y as f64) * (y as f64);
                }
            }
            let ratio = diff2.sqrt() / (1.0 + base2.sqrt());
            let result = LocalResult {
                params,
                mean_loss: rng.next_f64(),
                mean_acc: rng.next_f64(),
                steps: 2,
                weight: 1.0,
            };
            let v = UpdateValidator::new(*bound);
            match v.validate(&result, &broadcast) {
                Ok(()) if ratio > *bound => {
                    return Err(format!("ratio {ratio} > bound {bound} admitted"))
                }
                Err(Violation::NormBound { ratio: r }) => {
                    if ratio <= *bound {
                        return Err(format!("ratio {ratio} <= bound {bound} quarantined"));
                    }
                    if r.to_bits() != ratio.to_bits() {
                        return Err(format!("reported ratio {r} != spec'd {ratio}"));
                    }
                }
                Ok(()) => {}
                Err(other) => return Err(format!("finite update refused as {other:?}")),
            }
            // one poisoned value is always NonFinite, whatever the bound
            let mut poisoned = result.clone();
            let pi = (rng.next_u32() as usize) % poisoned.params.len();
            let e = (rng.next_u32() as usize) % poisoned.params[pi].len();
            poisoned.params[pi].data_mut()[e] = f32::NAN;
            if !matches!(
                UpdateValidator::default().validate(&poisoned, &broadcast),
                Err(Violation::NonFinite)
            ) {
                return Err("NaN-poisoned update not refused as NonFinite".into());
            }
            // a dropped tensor is always Shape
            let mut clipped = result;
            clipped.params.pop();
            if !matches!(
                UpdateValidator::default().validate(&clipped, &broadcast),
                Err(Violation::Shape)
            ) {
                return Err("short tensor list not refused as Shape".into());
            }
            Ok(())
        },
    );
}

/// Quarantine law: strikes escalate the bar exponentially (capped), a
/// barred client stays barred for exactly the advertised window, and
/// decay forgives — any strike sequence ends with the ledger empty
/// after enough clean rounds. Re-admission is the half that matters:
/// a ledger that never forgives turns one bad radio day into permanent
/// exclusion.
#[test]
fn prop_quarantine_bars_escalate_and_decay_to_empty() {
    use fluid::engine::chaos::{QUAR_BAR_BASE, QUAR_DECAY_EVERY};
    use fluid::engine::QuarantineLedger;
    check(
        Config { cases: 80, ..Default::default() },
        |g: &mut Gen| {
            // distinct, increasing client ids so each batch owns its
            // strike count
            let k = g.usize_in(1, 5);
            let clients: Vec<usize> = (0..k).map(|i| i * 7 + g.usize_in(0, 6)).collect();
            let strikes: Vec<usize> = clients.iter().map(|_| g.usize_in(1, 9)).collect();
            (clients, strikes)
        },
        |_| vec![],
        |(clients, strikes)| {
            let mut ledger = QuarantineLedger::default();
            let mut round = 0usize;
            let mut max_strikes = 0usize;
            for (&c, &n) in clients.iter().zip(strikes) {
                for _ in 0..n {
                    ledger.record(c, round);
                    round += 1;
                }
                max_strikes = max_strikes.max(n);
                // the bar doubles per strike up to the <<6 cap, counted
                // from the last strike: barred through its final round,
                // free the round after
                let bar = QUAR_BAR_BASE << (n - 1).min(6);
                let last = round - 1;
                if !ledger.is_barred(c, round) {
                    return Err(format!("client {c} free right after strike {n}"));
                }
                if !ledger.is_barred(c, last + bar - 1) {
                    return Err(format!("client {c} freed inside a {bar}-round bar"));
                }
                if ledger.is_barred(c, last + bar) {
                    return Err(format!("client {c} barred past its {bar}-round window"));
                }
            }
            // entries stay sorted by client and export/rebuild is faithful
            let entries = ledger.export();
            if !entries.windows(2).all(|w| w[0].client < w[1].client) {
                return Err("ledger entries not sorted by client".into());
            }
            let rebuilt = QuarantineLedger::from_entries(entries).map_err(|e| e.to_string())?;
            if rebuilt != ledger {
                return Err("export -> from_entries drifted".into());
            }
            // clean rounds forgive one strike per window: after
            // max_strikes windows past every bar, the ledger is empty
            let horizon = round + (QUAR_BAR_BASE << 7) + (max_strikes + 1) * QUAR_DECAY_EVERY;
            for r in round..=horizon {
                ledger.decay(r);
            }
            if !ledger.is_empty() {
                return Err(format!(
                    "{} entries survived {} clean rounds",
                    ledger.len(),
                    horizon - round
                ));
            }
            Ok(())
        },
    );
}

/// Vanish law (end-to-end): a vanished client's work must never reach
/// any server-side state — not the aggregate, not the stale buffer.
/// Under buffered sync with `vanish: 1.0` every participant vanishes
/// every round, so every checkpoint must show an *empty* stale buffer
/// and every record zero aggregated updates, while the run itself
/// completes gracefully (frozen params, NaN train metrics — never a
/// panic, never a phantom update).
#[test]
fn vanished_clients_never_reach_the_stale_buffer() {
    use fluid::coordinator::{self, ExperimentConfig};
    use fluid::dropout::PolicyKind;
    use fluid::engine::{ChaosConfig, ScenarioConfig, SyncMode};
    use fluid::snapshot::Snapshot;

    let dir = std::env::temp_dir().join(format!("fluid-vanish-law-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = ExperimentConfig::fleet("femnist_cnn", PolicyKind::Invariant, 2000, 64);
    cfg.rounds = 4;
    cfg.samples_per_client = 4;
    cfg.local_steps = 1;
    cfg.eval_every = cfg.rounds;
    cfg.scenario = ScenarioConfig::parse("storm").unwrap();
    cfg.seed = 6161;
    cfg.sync_mode = SyncMode::Buffered { k: 8 };
    cfg.chaos = Some(ChaosConfig {
        vanish: 1.0,
        ..ChaosConfig::parse("vanish").unwrap().unwrap()
    });
    cfg.checkpoint_every = 1;
    cfg.checkpoint_keep = cfg.rounds;
    cfg.checkpoint_dir = Some(dir.clone());
    let run = coordinator::run_sim(&cfg).expect("all-vanish run completes gracefully");
    for r in &run.records {
        assert!(r.vanished > 0, "round {}: nobody vanished at rate 1.0", r.round);
        assert_eq!(r.aggregated, 0, "round {}: phantom aggregation", r.round);
        assert_eq!(r.stale_folded, 0, "round {}: phantom stale fold", r.round);
    }
    for round in 1..=cfg.rounds {
        let path = dir.join(format!("snap-{round:06}.fluidsnap"));
        let bytes = std::fs::read(&path)
            .unwrap_or_else(|e| panic!("checkpoint {} unreadable: {e}", path.display()));
        let snap = Snapshot::decode(&bytes).expect("checkpoint decodes");
        assert!(
            snap.stale.is_empty(),
            "round {round}: {} vanished-client entries leaked into the stale buffer",
            snap.stale.len()
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}
