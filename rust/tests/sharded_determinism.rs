//! Cross-shard determinism & fault-injection suite for the sharded
//! multi-aggregator tree (DESIGN.md §11).
//!
//! Everything runs through `coordinator::run_sim` with
//! `ExperimentConfig::shards` varied, so the full stack is exercised:
//! shard slicing, scoped worker dispatch, wire-framed shard→root
//! messages, the `tree_reduce` ordered-concat fold, and the unchanged
//! engine float path above the seam.
//!
//! Pinned invariants:
//! * a 50k-client storm fleet is bit-identical across
//!   `--shards` ∈ {1, 2, 4, 8} × `--threads` ∈ {1, 4};
//! * shard-count invariance holds for all three `SamplerKind`s and all
//!   three `SyncMode`s (each compared against the 1-shard serial run);
//! * snapshots carry no shard state: checkpoint-under-4-shards resumes
//!   bit-identically under 1 shard (and the reverse, and under 8) —
//!   the N→M rule;
//! * a shard killed mid-round surfaces a typed [`ShardFault`] after the
//!   due checkpoint was written, leaks no partial state (the resumed
//!   run matches an uninterrupted control bit-for-bit), and with
//!   `--shard-retry` the run completes bit-identically instead.
//!
//! Wall-clock fields are host measurements and excluded, exactly as in
//! `tests/determinism.rs`.

use fluid::coordinator::{self, ExperimentConfig, ExperimentResult};
use fluid::dropout::PolicyKind;
use fluid::engine::{ScenarioConfig, ShardFault, SyncMode};
use fluid::fl::SamplerKind;
use std::time::Instant;

/// NaN-aware bitwise equality.
fn eq_f64(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

/// Bitwise comparison of everything the algorithm (not the host clock)
/// produced — the same contract as `tests/determinism.rs`.
fn assert_bit_identical(a: &ExperimentResult, b: &ExperimentResult, ctx: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{ctx}: record count");
    for (x, y) in a.records.iter().zip(&b.records) {
        let rctx = format!("{ctx}: round {}", x.round);
        assert_eq!(x.round, y.round, "{rctx}");
        assert_eq!(x.cohort, y.cohort, "{rctx}: cohort");
        assert_eq!(x.straggler_ids, y.straggler_ids, "{rctx}: stragglers");
        assert_eq!(x.straggler_rates, y.straggler_rates, "{rctx}: rates");
        assert!(eq_f64(x.round_time, y.round_time), "{rctx}: round_time");
        assert!(eq_f64(x.vtime, y.vtime), "{rctx}: vtime");
        assert!(eq_f64(x.t_target, y.t_target), "{rctx}: t_target");
        assert!(
            eq_f64(x.straggler_time, y.straggler_time),
            "{rctx}: straggler_time"
        );
        assert!(eq_f64(x.train_loss, y.train_loss), "{rctx}: train_loss");
        assert!(eq_f64(x.train_acc, y.train_acc), "{rctx}: train_acc");
        assert!(eq_f64(x.test_loss, y.test_loss), "{rctx}: test_loss");
        assert!(eq_f64(x.test_acc, y.test_acc), "{rctx}: test_acc");
        assert!(
            eq_f64(x.invariant_fraction, y.invariant_fraction),
            "{rctx}: invariant_fraction"
        );
        assert_eq!(x.aggregated, y.aggregated, "{rctx}: aggregated");
        assert_eq!(x.dropped_updates, y.dropped_updates, "{rctx}: dropped");
        assert_eq!(x.stale_folded, y.stale_folded, "{rctx}: stale");
    }
    assert!(eq_f64(a.final_test_acc, b.final_test_acc), "{ctx}");
    assert!(eq_f64(a.final_test_loss, b.final_test_loss), "{ctx}");
    assert!(eq_f64(a.total_vtime, b.total_vtime), "{ctx}");
    assert_eq!(a.seed, b.seed, "{ctx}");
}

/// The 50k storm fleet, sized (like `tests/determinism.rs`) so a
/// debug-profile `cargo test` sweep over many runs stays in budget.
fn storm_50k_cfg(rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::fleet("femnist_cnn", PolicyKind::Invariant, 50_000, 256);
    cfg.rounds = rounds;
    cfg.samples_per_client = 4;
    cfg.local_steps = 1;
    cfg.eval_every = rounds;
    cfg.scenario = ScenarioConfig::parse("storm").unwrap();
    cfg.seed = 20_260_729;
    cfg
}

/// A cheaper 2k storm fleet for the checkpoint/resume and fault legs.
fn storm_2k_cfg(seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::fleet("femnist_cnn", PolicyKind::Invariant, 2000, 64);
    cfg.rounds = 6;
    cfg.samples_per_client = 4;
    cfg.local_steps = 1;
    cfg.eval_every = 3;
    cfg.scenario = ScenarioConfig::parse("storm").unwrap();
    cfg.seed = seed;
    cfg
}

/// Unique scratch directory for snapshot files; removed (best-effort) by
/// the tests that use it.
fn ckpt_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("fluid-sharded-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn snap_path(dir: &std::path::Path, round: usize) -> std::path::PathBuf {
    dir.join(format!("snap-{round:06}.fluidsnap"))
}

/// The headline grid: the 50k storm fleet replays bit-identically at
/// every `--shards` ∈ {1, 2, 4, 8} × `--threads` ∈ {1, 4} against the
/// serial 1-shard / 1-thread baseline.
#[test]
fn storm_50k_is_bit_identical_at_every_shard_and_thread_count() {
    let t0 = Instant::now();
    let mut results = Vec::new();
    for shards in [1usize, 2, 4, 8] {
        for threads in [1usize, 4] {
            let mut cfg = storm_50k_cfg(3);
            cfg.shards = shards;
            cfg.threads = threads;
            results.push((shards, threads, coordinator::run_sim(&cfg).unwrap()));
        }
    }
    let (_, _, base) = &results[0];
    assert_eq!(base.records.len(), 3);
    for (shards, threads, r) in &results[1..] {
        assert_bit_identical(base, r, &format!("shards={shards} threads={threads}"));
    }
    let secs = t0.elapsed().as_secs_f64();
    assert!(secs < 240.0, "shard×thread grid took {secs:.1}s (budget 240s)");
}

/// Every `SamplerKind` is shard-count invariant on the 50k storm fleet:
/// the 4-shard / 4-thread run matches the 1-shard / 1-thread run.
#[test]
fn storm_50k_samplers_are_shard_count_invariant() {
    for sampler in [
        SamplerKind::Uniform,
        SamplerKind::WeightedByData,
        SamplerKind::AvailabilityAware,
    ] {
        let mut cfg = storm_50k_cfg(2);
        cfg.sampler = sampler;
        let serial = coordinator::run_sim(&cfg).unwrap();
        cfg.shards = 4;
        cfg.threads = 4;
        let sharded = coordinator::run_sim(&cfg).unwrap();
        assert_bit_identical(
            &serial,
            &sharded,
            &format!("sampler={} shards=4", sampler.name()),
        );
    }
}

/// Every `SyncMode` is shard-count invariant on the 50k storm fleet —
/// late arrivals, deadlines and buffered folds all happen at the root,
/// above the shard seam, so the shard count must not be observable.
#[test]
fn storm_50k_sync_modes_are_shard_count_invariant() {
    for (name, mode) in [
        ("full", SyncMode::FullBarrier),
        ("deadline", SyncMode::Deadline { multiple_of_t_target: 1.25 }),
        ("buffered", SyncMode::Buffered { k: 48 }),
    ] {
        let mut cfg = storm_50k_cfg(3);
        cfg.sync_mode = mode;
        let serial = coordinator::run_sim(&cfg).unwrap();
        cfg.shards = 4;
        cfg.threads = 4;
        let sharded = coordinator::run_sim(&cfg).unwrap();
        assert_bit_identical(&serial, &sharded, &format!("sync={name} shards=4"));
    }
}

/// The N→M resume rule: snapshots carry no shard state, so a checkpoint
/// taken under 4 shards resumes bit-identically under 1 shard, and a
/// 1-shard checkpoint resumes under 4 (and 8) — all against a single
/// uninterrupted serial control.
#[test]
fn snapshot_under_n_shards_resumes_bit_identically_under_m() {
    let control = coordinator::run_sim(&storm_2k_cfg(4411)).unwrap();

    // checkpoint under 4 shards, resume under 1 (and 8)
    let dir = ckpt_dir("n4");
    let mut cfg = storm_2k_cfg(4411);
    cfg.shards = 4;
    cfg.checkpoint_every = 2;
    cfg.checkpoint_keep = cfg.rounds;
    cfg.checkpoint_dir = Some(dir.clone());
    let four = coordinator::run_sim(&cfg).unwrap();
    assert_bit_identical(&control, &four, "uninterrupted 4-shard run");
    for (resume_shards, at) in [(1usize, 2usize), (1, 4), (8, 2)] {
        let mut rcfg = storm_2k_cfg(4411);
        rcfg.shards = resume_shards;
        rcfg.resume_from = Some(snap_path(&dir, at));
        let resumed = coordinator::run_sim(&rcfg).unwrap();
        assert_bit_identical(
            &control,
            &resumed,
            &format!("snap under 4 shards, resume@{at} under {resume_shards}"),
        );
    }
    let _ = std::fs::remove_dir_all(&dir);

    // the reverse: checkpoint under 1 shard, resume under 4
    let dir = ckpt_dir("n1");
    let mut cfg = storm_2k_cfg(4411);
    cfg.checkpoint_every = 2;
    cfg.checkpoint_keep = cfg.rounds;
    cfg.checkpoint_dir = Some(dir.clone());
    coordinator::run_sim(&cfg).unwrap();
    let mut rcfg = storm_2k_cfg(4411);
    rcfg.shards = 4;
    rcfg.resume_from = Some(snap_path(&dir, 4));
    let resumed = coordinator::run_sim(&rcfg).unwrap();
    assert_bit_identical(&control, &resumed, "snap under 1 shard, resume@4 under 4");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A shard killed mid-round surfaces a typed [`ShardFault`] (the engine
/// aborts the round before touching global state), the due checkpoint
/// is on disk, and the run resumed from it — under a *different* shard
/// count, without the crash knob — matches an uninterrupted control bit
/// for bit. No partial state leaks.
#[test]
fn killed_shard_checkpoints_then_resumes_bit_identically() {
    let dir = ckpt_dir("kill");
    let mut cfg = storm_2k_cfg(9177);
    cfg.shards = 4;
    cfg.shard_crash_after = Some((2, 4));
    cfg.checkpoint_every = 2;
    cfg.checkpoint_dir = Some(dir.clone());
    let err = coordinator::run_sim(&cfg).unwrap_err();
    let fault = err
        .downcast_ref::<ShardFault>()
        .unwrap_or_else(|| panic!("expected ShardFault, got: {err:#}"));
    assert_eq!((fault.shard, fault.round), (2, 4));
    assert!(snap_path(&dir, 4).exists(), "due checkpoint missing at shard kill");

    let control = coordinator::run_sim(&storm_2k_cfg(9177)).unwrap();
    let mut rcfg = storm_2k_cfg(9177);
    rcfg.shards = 1; // resume under a different shard count (N→M)
    rcfg.resume_from = Some(dir.clone());
    let resumed = coordinator::run_sim(&rcfg).unwrap();
    assert_bit_identical(&control, &resumed, "resume after shard kill");
    let _ = std::fs::remove_dir_all(&dir);
}

/// With `--shard-retry` the root re-dispatches the dead shard's slice
/// instead of failing; purity makes the completed run bit-identical to
/// the serial control.
#[test]
fn shard_retry_completes_bit_identically_despite_the_kill() {
    let control = coordinator::run_sim(&storm_2k_cfg(5521)).unwrap();
    let mut cfg = storm_2k_cfg(5521);
    cfg.shards = 4;
    cfg.shard_crash_after = Some((1, 3));
    cfg.shard_retry = true;
    let retried = coordinator::run_sim(&cfg).unwrap();
    assert_bit_identical(&control, &retried, "retry after shard kill");
}

/// A fault aimed at a round the run never reaches changes nothing: the
/// sharded run completes and stays bit-identical to the control.
#[test]
fn unfired_fault_knob_is_inert() {
    let control = coordinator::run_sim(&storm_2k_cfg(808)).unwrap();
    let mut cfg = storm_2k_cfg(808);
    cfg.shards = 2;
    cfg.shard_crash_after = Some((0, 1000));
    let run = coordinator::run_sim(&cfg).unwrap();
    assert_bit_identical(&control, &run, "unfired shard fault");
}
