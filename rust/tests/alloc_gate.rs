//! Allocation gate for the aggregation hot path (ISSUE 4 acceptance):
//! once the scratch arena is warm, `fedavg_into` must perform **zero
//! heap allocations in its inner path** — accumulators, kept-weight
//! vectors, denominators and the output tensors themselves all come
//! from the reused [`AggScratch`]. A counting global allocator measures
//! the steady-state call; the only permitted allocation is the O(params)
//! `Vec<Tensor>` shell of the return value (a few hundred bytes),
//! nothing proportional to the parameter count.
//!
//! The fused observation sweep is gated the same way. Measured with
//! `threads = 1` (the inline, spawn-free path) so thread-stack setup
//! does not pollute the counter; the thread-count property tests pin
//! that the parallel path computes identical bytes.
//!
//! The shard wire codec (DESIGN.md §11) gets its own gate: steady-state
//! `encode_message` reuses its blob/frame buffers outright, and
//! `decode_message` allocates only O(message) container shells — the
//! tensor columns themselves come back out of the [`AggScratch`]
//! recycle pool, never a per-column fresh `Vec`.

use fluid::fl::{
    fedavg_into, pack_result, sample_cohort, AggScratch, AggregateMode, ClientUpdate, Compression,
    DeltaPayload, Fleet, SamplerKind,
};
use fluid::dropout::{InvariantConfig, InvariantDropout, MaskSet};
use fluid::model::sim_spec;
use fluid::tensor::Tensor;
use fluid::util::prng::Pcg32;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        BYTES.fetch_add(layout.size() as u64, Ordering::SeqCst);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        BYTES.fetch_add(new_size as u64, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        BYTES.fetch_add(layout.size() as u64, Ordering::SeqCst);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Bytes allocated while running `f`.
fn allocated_during<R>(f: impl FnOnce() -> R) -> (u64, R) {
    let before = BYTES.load(Ordering::SeqCst);
    let r = f();
    (BYTES.load(Ordering::SeqCst) - before, r)
}

/// Minimum bytes allocated over `reps` runs of `f` — the counter is
/// process-global, so a concurrent harness thread can inflate a single
/// window; it cannot inflate every one.
fn min_allocated(reps: usize, mut f: impl FnMut() -> u64) -> u64 {
    (0..reps).map(|_| f()).min().unwrap_or(0)
}

#[test]
fn hot_path_is_allocation_free_at_steady_state() {
    let spec = sim_spec("femnist_cnn");
    let global = spec.init_params(2);
    let mut rng = Pcg32::new(7, 3);
    let updates: Vec<ClientUpdate> = (0..32)
        .map(|i| {
            let keep: Vec<Vec<bool>> = spec
                .masks
                .iter()
                .map(|m| (0..m.size).map(|_| rng.next_f32() < 0.8).collect())
                .collect();
            ClientUpdate {
                payload: DeltaPayload::DenseF32(spec.init_params(100 + i)),
                weight: 8.0,
                mask: if i % 3 == 0 {
                    MaskSet::from_keep(&spec, &keep)
                } else {
                    MaskSet::full(&spec)
                },
                staleness: 0,
            }
        })
        .collect();

    let mut scratch = AggScratch::new();
    // the permitted residue: the return value's Vec<Tensor> shell
    let shell = (global.len() * std::mem::size_of::<Tensor>()) as u64;

    for mode in [AggregateMode::Plain, AggregateMode::OwnershipWeighted] {
        // warm: grows the arena and seeds the output pool
        let out = fedavg_into(&spec, &global, &updates, mode, 1, &mut scratch);
        // it computes the same aggregation as the cold unpooled path
        let fresh = fluid::fl::fedavg(&spec, &global, &updates, mode);
        for (a, b) in out.iter().zip(&fresh) {
            assert_eq!(a, b, "{mode:?}: pooled result differs from cold path");
        }
        scratch.recycle(out);
        // steady state: nothing but the shell may allocate
        let bytes = min_allocated(5, || {
            let (bytes, out) = allocated_during(|| {
                fedavg_into(&spec, &global, &updates, mode, 1, &mut scratch)
            });
            scratch.recycle(out);
            bytes
        });
        assert!(
            bytes <= shell + 64,
            "{mode:?}: steady-state fedavg allocated {bytes} bytes (shell is {shell})"
        );
    }
}

#[test]
fn fused_observe_is_allocation_free_at_steady_state() {
    let spec = sim_spec("shakespeare_lstm");
    let mut rng = Pcg32::new(11, 5);
    let deltas: Vec<Vec<Tensor>> = (0..8)
        .map(|_| {
            spec.masks
                .iter()
                .map(|m| {
                    Tensor::from_vec(
                        &[m.size],
                        (0..m.size).map(|_| rng.next_f32() * 0.2).collect(),
                    )
                })
                .collect()
        })
        .collect();
    let mut inv = InvariantDropout::new(&spec, InvariantConfig::default());
    let mut scratch = AggScratch::new();
    // first observation initializes thresholds (and may allocate minima)
    inv.observe_with(&deltas, 1, &mut scratch);
    inv.observe_with(&deltas, 1, &mut scratch);
    let bytes =
        min_allocated(5, || allocated_during(|| inv.observe_with(&deltas, 1, &mut scratch)).0);
    assert_eq!(bytes, 0, "steady-state observe allocated {bytes} bytes");
}

#[test]
fn fleet_sampling_is_allocation_free_at_steady_state() {
    // ISSUE 6 satellite: the per-round `seen = vec![false; n]` bitmap and
    // cumulative-vector rebuild are gone — at steady state a cohort draw
    // may allocate nothing beyond the returned cohort Vec itself (the
    // sparse Fisher–Yates map and the duplicate-rejection set are hoisted
    // into the sampler and reused with retained capacity).
    let n = 20_000usize;
    let mut fleet = Fleet::synthetic_pool(n, 7);
    fleet.set_data_lens((0..n).map(|c| 4 + c % 13));
    for c in (0..n).step_by(5) {
        fleet.set_available(c, false);
    }
    let k = 256usize;
    let shell = (k * std::mem::size_of::<usize>()) as u64;
    let mut rng = Pcg32::new(3, 1);
    for kind in [
        SamplerKind::Uniform,
        SamplerKind::WeightedByData,
        SamplerKind::AvailabilityAware,
    ] {
        // warm: the reusable map/set reach their high-water capacity
        for _ in 0..10 {
            let s = sample_cohort(&mut fleet, kind, k, &mut rng);
            assert_eq!(s.len(), k, "{}", kind.name());
        }
        let bytes = min_allocated(5, || {
            allocated_during(|| sample_cohort(&mut fleet, kind, k, &mut rng)).0
        });
        assert!(
            bytes <= shell + 256,
            "{}: steady-state draw allocated {bytes} bytes (cohort shell is {shell})",
            kind.name()
        );
    }
}

#[test]
fn churn_deltas_are_allocation_free_at_steady_state() {
    let n = 20_000usize;
    let mut fleet = Fleet::synthetic_pool(n, 7);
    let mut rng = Pcg32::new(5, 2);
    // warm with full flips so the delta scratch hits its high-water mark
    fleet.apply_churn(1.0, 1.0, &mut rng); // everyone leaves
    fleet.apply_churn(1.0, 1.0, &mut rng); // everyone rejoins
    let bytes = min_allocated(5, || {
        allocated_during(|| fleet.apply_churn(0.05, 0.30, &mut rng)).0
    });
    assert_eq!(bytes, 0, "steady-state churn delta allocated {bytes} bytes");
}

#[test]
fn wire_codec_reuses_buffers_at_steady_state() {
    use fluid::engine::wire::{decode_message, encode_message, ShardMessage};
    use fluid::fl::LocalResult;

    // a realistic shard slice: 16 clients, a 64x32 weight + 32-bias pair
    // each, so the column data dwarfs every container shell
    let nitems = 16usize;
    let shape = [64usize, 32];
    let elems: usize = shape.iter().product();
    let items: Vec<Result<LocalResult, String>> = (0..nitems)
        .map(|i| {
            Ok(LocalResult {
                params: vec![
                    Tensor::from_vec(&shape, vec![0.5 + i as f32; elems]),
                    Tensor::from_vec(&[shape[1]], vec![1.0; shape[1]]),
                ],
                mean_loss: 0.25,
                mean_acc: 0.5,
                steps: 4,
                weight: 6.0,
            })
        })
        .collect();
    let msg = ShardMessage::Results { shard: 1, round: 9, base: 32, items };
    let data_bytes = (nitems * (elems + shape[1]) * 4) as u64;

    let (mut blob, mut frame) = (Vec::new(), Vec::new());
    let mut scratch = AggScratch::new();
    // warm: blob/frame reach their high-water capacity and the recycle
    // pool learns both tensor shapes
    for _ in 0..2 {
        encode_message(&msg, &mut blob, &mut frame);
        let decoded = decode_message(&frame, &mut scratch).unwrap();
        if let ShardMessage::Results { items, .. } = decoded {
            for r in items.into_iter().flatten() {
                scratch.recycle(r.params);
            }
        }
    }

    // steady-state encode rewrites the same two buffers in place
    let enc = min_allocated(5, || {
        allocated_during(|| encode_message(&msg, &mut blob, &mut frame)).0
    });
    assert!(enc <= 64, "steady-state wire encode allocated {enc} bytes");

    // steady-state decode: O(message) shells (item/param vectors, shape
    // headers), never the columns — those come from the pool
    let shell_budget = (nitems as u64) * 512 + 8192;
    assert!(
        shell_budget * 4 < data_bytes,
        "gate budget {shell_budget} is not far below the {data_bytes}-byte column data"
    );
    let dec = min_allocated(5, || {
        let (bytes, decoded) =
            allocated_during(|| decode_message(&frame, &mut scratch).unwrap());
        if let ShardMessage::Results { items, .. } = decoded {
            for r in items.into_iter().flatten() {
                scratch.recycle(r.params);
            }
        }
        bytes
    });
    assert!(
        dec <= shell_budget,
        "steady-state wire decode allocated {dec} bytes (shell budget {shell_budget}, \
         column data {data_bytes})"
    );
}

#[test]
fn packed_payload_codec_allocates_packed_not_dense_bytes() {
    use fluid::engine::wire::{decode_message, encode_message, ShardMessage};
    use fluid::fl::LocalResult;

    // a compressed shard slice: 8 clients of femnist-sim results packed
    // at keep-rate 1/2, so the dense tensor data is ~2x the wire payload
    let spec = sim_spec("femnist_cnn");
    let keep: Vec<Vec<bool>> = spec
        .masks
        .iter()
        .map(|m| (0..m.size).map(|j| j % 2 == 0).collect())
        .collect();
    let mask = MaskSet::from_keep(&spec, &keep);
    let mut scratch = AggScratch::new();
    let nitems = 8usize;
    let per_client: u64 = spec
        .params
        .iter()
        .map(|p| 4 * p.shape.iter().product::<usize>() as u64)
        .sum();
    let dense_bytes: u64 = nitems as u64 * per_client;
    let items: Vec<Result<fluid::fl::PackedResult, String>> = (0..nitems)
        .map(|i| {
            let res = LocalResult {
                params: spec.init_params(40 + i as u64),
                mean_loss: 0.5,
                mean_acc: 0.25,
                steps: 3,
                weight: 5.0,
            };
            Ok(pack_result(res, &mask, &spec, Compression::Sparse, &mut scratch))
        })
        .collect();
    let packed_bytes: u64 = items
        .iter()
        .map(|r| r.as_ref().unwrap().payload.wire_bytes() as u64)
        .sum();
    assert!(
        packed_bytes * 3 < dense_bytes * 2,
        "packed {packed_bytes} bytes is not well below dense {dense_bytes} at rate 0.5"
    );
    let msg = ShardMessage::Packed { shard: 2, round: 5, base: 16, items };

    let (mut blob, mut frame) = (Vec::new(), Vec::new());
    // warm: blob/frame reach their high-water capacity
    for _ in 0..2 {
        encode_message(&msg, &mut blob, &mut frame);
        decode_message(&frame, &mut scratch).unwrap();
    }

    // steady-state encode rewrites the same two buffers in place
    let enc = min_allocated(5, || {
        allocated_during(|| encode_message(&msg, &mut blob, &mut frame)).0
    });
    assert!(enc <= 64, "steady-state packed encode allocated {enc} bytes");

    // steady-state decode allocates the packed value vectors themselves
    // (they travel inside the payload, so they cannot come from a pool)
    // plus O(message) container shells — never the dense tensor data
    let shell_budget = packed_bytes + (nitems as u64) * 512 + 4096;
    assert!(
        shell_budget < dense_bytes,
        "gate budget {shell_budget} is not below the {dense_bytes}-byte dense data"
    );
    let dec = min_allocated(5, || {
        allocated_during(|| decode_message(&frame, &mut scratch).unwrap()).0
    });
    assert!(
        dec <= shell_budget,
        "steady-state packed decode allocated {dec} bytes \
         (budget {shell_budget}, dense data {dense_bytes})"
    );
}

#[test]
fn chaos_clean_path_is_allocation_free() {
    // DESIGN.md §13: the chaos plane's always-on pieces — validating a
    // clean update against the broadcast model, the per-participant
    // quarantine bar lookup, and the per-round ledger decay — sit on
    // every round's hot path whether or not chaos is configured, so
    // none of them may allocate. (Recording a strike is the fault path
    // and may grow the ledger; it is not gated.)
    use fluid::engine::{QuarantineLedger, UpdateValidator};
    use fluid::fl::LocalResult;

    let spec = sim_spec("femnist_cnn");
    let broadcast = spec.init_params(2);
    let result = LocalResult {
        params: spec.init_params(9),
        mean_loss: 0.25,
        mean_acc: 0.5,
        steps: 4,
        weight: 6.0,
    };
    let validator = UpdateValidator::default();
    assert!(
        validator.validate(&result, &broadcast).is_ok(),
        "gate input must be a clean update"
    );
    let bytes = min_allocated(5, || {
        allocated_during(|| validator.validate(&result, &broadcast).unwrap()).0
    });
    assert_eq!(bytes, 0, "clean-path validate allocated {bytes} bytes");

    // a populated ledger: bar lookups and decay sweeps are in-place
    let mut ledger = QuarantineLedger::default();
    for c in 0..64usize {
        ledger.record(c * 3, c);
        ledger.record(c * 3, c + 1); // second strike, extends the bar
    }
    let probe = min_allocated(5, || {
        allocated_during(|| {
            let mut barred = 0usize;
            for c in 0..256usize {
                if ledger.is_barred(c, 100) {
                    barred += 1;
                }
            }
            barred
        })
        .0
    });
    assert_eq!(probe, 0, "quarantine bar lookups allocated {probe} bytes");

    let decay = min_allocated(5, || {
        let (bytes, _) = allocated_during(|| {
            // sweeps that forgive strikes and drop entries still mutate
            // the entry vector in place
            for r in 0..200usize {
                ledger.decay(r);
            }
        });
        // re-arm for the next rep so every window does real work
        for c in 0..64usize {
            ledger.record(c * 3, c);
        }
        bytes
    });
    assert_eq!(decay, 0, "ledger decay allocated {decay} bytes");
}
