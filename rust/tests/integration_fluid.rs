//! Integration: the full FLuID coordinator over real artifacts.
//!
//! Requires `make artifacts`; every test skips gracefully otherwise.

use fluid::coordinator::{self, ExperimentConfig};
use fluid::dropout::PolicyKind;
use fluid::runtime::Session;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have(model: &str) -> bool {
    // without the xla feature the runtime is a stub: Session::new always
    // fails, so artifact presence alone is not enough to run
    cfg!(feature = "xla") && artifacts_dir().join(format!("{model}_manifest.json")).exists()
}

fn quick_cfg(policy: PolicyKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::mobile("femnist_cnn", policy);
    cfg.rounds = 8;
    cfg.samples_per_client = 30;
    cfg.local_steps = 2;
    cfg.eval_every = 4;
    cfg.lr = 0.01;
    cfg
}

#[test]
fn full_loop_invariant_policy() {
    if !have("femnist_cnn") {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let sess = Session::new(artifacts_dir()).unwrap();
    let res = coordinator::run(&sess, &quick_cfg(PolicyKind::Invariant)).unwrap();
    assert_eq!(res.records.len(), 8);
    // loss must drop over the run
    let first = res.records.first().unwrap().train_loss;
    let last = res.records.last().unwrap().train_loss;
    assert!(last < first, "loss did not drop: {first} -> {last}");
    // a straggler must be detected after round 0 and get a sub-model
    let det_rounds = res
        .records
        .iter()
        .skip(1)
        .filter(|r| !r.straggler_ids.is_empty())
        .count();
    assert!(det_rounds >= 6, "straggler detected in only {det_rounds}/7 rounds");
    for r in res.records.iter().skip(2) {
        for &rate in &r.straggler_rates {
            assert!(rate < 1.0, "straggler kept the full model");
        }
    }
    // invariant fraction becomes non-trivial
    assert!(res.records.last().unwrap().invariant_fraction > 0.01);
    assert!(res.final_test_acc.is_finite());
}

#[test]
fn straggler_time_within_10pct_of_target() {
    // Fig 4a claim: with FLuID the straggler lands within ~10% of T_target.
    if !have("femnist_cnn") {
        return;
    }
    let sess = Session::new(artifacts_dir()).unwrap();
    let mut cfg = quick_cfg(PolicyKind::Invariant);
    cfg.rounds = 10;
    let res = coordinator::run(&sess, &cfg).unwrap();
    // skip warmup rounds; look at steady state
    let steady: Vec<&fluid::coordinator::RoundRecord> = res
        .records
        .iter()
        .skip(3)
        .filter(|r| !r.straggler_ids.is_empty())
        .collect();
    assert!(!steady.is_empty());
    let mut ok = 0;
    for r in &steady {
        if (r.straggler_time - r.t_target).abs() / r.t_target <= 0.15 {
            ok += 1;
        }
    }
    assert!(
        ok * 2 >= steady.len(),
        "straggler within target band in only {ok}/{} rounds",
        steady.len()
    );
}

#[test]
fn fluid_is_faster_than_vanilla() {
    if !have("femnist_cnn") {
        return;
    }
    let sess = Session::new(artifacts_dir()).unwrap();
    let fluid_res = coordinator::run(&sess, &quick_cfg(PolicyKind::Invariant)).unwrap();
    let vanilla = coordinator::run(&sess, &quick_cfg(PolicyKind::None)).unwrap();
    assert!(
        fluid_res.total_vtime < vanilla.total_vtime,
        "FLuID {:.1}s not faster than vanilla {:.1}s",
        fluid_res.total_vtime,
        vanilla.total_vtime
    );
}

#[test]
fn all_policies_complete_and_learn() {
    if !have("femnist_cnn") {
        return;
    }
    let sess = Session::new(artifacts_dir()).unwrap();
    for policy in [
        PolicyKind::None,
        PolicyKind::Random,
        PolicyKind::Ordered,
        PolicyKind::Invariant,
        PolicyKind::Exclude,
    ] {
        let mut cfg = quick_cfg(policy);
        cfg.fixed_rate = Some(0.75);
        let res = coordinator::run(&sess, &cfg)
            .unwrap_or_else(|e| panic!("{} failed: {e:#}", policy.name()));
        let first = res.records.first().unwrap().train_loss;
        let last = res.records.last().unwrap().train_loss;
        assert!(
            last < first,
            "{}: loss did not drop ({first} -> {last})",
            policy.name()
        );
    }
}

#[test]
fn fluctuation_changes_straggler_identity() {
    if !have("femnist_cnn") {
        return;
    }
    let sess = Session::new(artifacts_dir()).unwrap();
    let mut cfg = quick_cfg(PolicyKind::Invariant);
    cfg.rounds = 16;
    cfg.fluctuation = true;
    let res = coordinator::run(&sess, &cfg).unwrap();
    let ids: std::collections::BTreeSet<usize> = res
        .records
        .iter()
        .flat_map(|r| r.straggler_ids.iter().copied())
        .collect();
    assert!(
        ids.len() >= 2,
        "straggler identity never changed despite fluctuation: {ids:?}"
    );
}

#[test]
fn client_sampling_runs() {
    if !have("femnist_cnn") {
        return;
    }
    let sess = Session::new(artifacts_dir()).unwrap();
    let mut cfg = ExperimentConfig::scale("femnist_cnn", PolicyKind::Invariant, 40);
    cfg.rounds = 5;
    cfg.sample_fraction = 0.25;
    cfg.samples_per_client = 12;
    cfg.local_steps = 1;
    cfg.eval_every = 5;
    cfg.lr = 0.01;
    let res = coordinator::run(&sess, &cfg).unwrap();
    assert_eq!(res.records.len(), 5);
    // sampled stragglers never exceed 20% of the sampled cohort (10)
    for r in &res.records {
        assert!(r.straggler_ids.len() <= 2, "{:?}", r.straggler_ids);
    }
}

#[test]
fn missing_model_fails_cleanly() {
    // under --no-default-features the stub runtime refuses to build a
    // session at all — that *is* the clean failure for this config
    let Ok(sess) = Session::new(artifacts_dir()) else {
        assert!(
            !cfg!(feature = "xla"),
            "session creation failed with the xla runtime available"
        );
        return;
    };
    let mut cfg = quick_cfg(PolicyKind::None);
    cfg.model = "not_a_model".into();
    let err = coordinator::run(&sess, &cfg).unwrap_err().to_string();
    assert!(err.contains("not_a_model"), "{err}");
}

#[test]
fn exclude_policy_skips_straggler_updates() {
    if !have("femnist_cnn") {
        return;
    }
    let sess = Session::new(artifacts_dir()).unwrap();
    let mut cfg = quick_cfg(PolicyKind::Exclude);
    cfg.rounds = 6;
    let res = coordinator::run(&sess, &cfg).unwrap();
    // straggler still detected (timing), but masks stay full
    assert!(res
        .records
        .iter()
        .skip(2)
        .all(|r| r.straggler_rates.iter().all(|&x| x < 1.0 || x == 1.0)));
    assert!(res.final_test_acc.is_finite());
}

/// Resume equivalence on the PJRT-backed LocalExecutor path: a run
/// resumed from a mid-run snapshot must reproduce the uninterrupted
/// run's remaining rounds bit-for-bit — the same contract the
/// determinism suite pins for the sim backend, asserted here against
/// real artifacts (ISSUE: both feature configurations).
#[test]
fn checkpoint_resume_is_bit_identical_over_artifacts() {
    if !have("femnist_cnn") {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let dir = std::env::temp_dir().join(format!("fluid-xla-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let sess = Session::new(artifacts_dir()).unwrap();

    let mut cfg = quick_cfg(PolicyKind::Invariant);
    cfg.rounds = 6;
    cfg.checkpoint_every = 2;
    cfg.checkpoint_keep = 8;
    cfg.checkpoint_dir = Some(dir.clone());
    let control = coordinator::run(&sess, &cfg).unwrap();

    let mut rcfg = quick_cfg(PolicyKind::Invariant);
    rcfg.rounds = 6;
    rcfg.resume_from = Some(dir.join("snap-000004.fluidsnap"));
    let resumed = coordinator::run(&sess, &rcfg).unwrap();

    let eq = |a: f64, b: f64| a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan());
    assert_eq!(control.records.len(), resumed.records.len());
    for (x, y) in control.records.iter().zip(&resumed.records) {
        let ctx = format!("round {}", x.round);
        assert_eq!(x.cohort, y.cohort, "{ctx}: cohort");
        assert_eq!(x.straggler_ids, y.straggler_ids, "{ctx}: stragglers");
        assert_eq!(x.straggler_rates, y.straggler_rates, "{ctx}: rates");
        assert!(eq(x.round_time, y.round_time), "{ctx}: round_time");
        assert!(eq(x.vtime, y.vtime), "{ctx}: vtime");
        assert!(eq(x.train_loss, y.train_loss), "{ctx}: train_loss");
        assert!(eq(x.test_acc, y.test_acc), "{ctx}: test_acc");
        assert!(
            eq(x.invariant_fraction, y.invariant_fraction),
            "{ctx}: invariant_fraction"
        );
        assert_eq!(x.aggregated, y.aggregated, "{ctx}: aggregated");
    }
    assert!(eq(control.final_test_acc, resumed.final_test_acc));
    assert!(eq(control.total_vtime, resumed.total_vtime));
    let _ = std::fs::remove_dir_all(&dir);
}
