//! Determinism & recovery suite for the chaos plane (DESIGN.md §13).
//!
//! Everything runs through `coordinator::run_sim` with
//! `ExperimentConfig::chaos` set, so the full stack is exercised: the
//! root-side fault draws, the deadline drop of vanished/hung clients,
//! the always-on validator + quarantine ledger, the `--quorum` floor,
//! and the sharded tree's bounded retry budget.
//!
//! Pinned invariants:
//! * a seeded chaos storm fleet is bit-identical — telemetry included —
//!   across `--threads` ∈ {1, 4} × `--shards` ∈ {1, 4}: faults are pure
//!   data drawn per `(round, client)`, never a function of topology;
//! * a zero-rate chaos script is inert: bit-identical to `--chaos none`;
//! * checkpoint-under-chaos resumes bit-for-bit, including the
//!   QuarantineLedger section — a resumed run re-bars exactly the
//!   clients the killed run had quarantined;
//! * a quorum failure surfaces as a typed [`QuorumFailed`] (never a
//!   panic, never a silent half-round), and the checkpoint it stopped
//!   at resumes cleanly under a relaxed floor;
//! * an exhausted shard-retry budget surfaces as a typed [`ShardFault`];
//!   one more unit of budget completes the same run.
//!
//! Wall-clock fields are host measurements and excluded, exactly as in
//! `tests/determinism.rs`.

use fluid::coordinator::{self, ExperimentConfig, ExperimentResult};
use fluid::dropout::PolicyKind;
use fluid::engine::{ChaosConfig, QuorumFailed, ScenarioConfig, ShardFault};
use fluid::snapshot::Snapshot;

/// NaN-aware bitwise equality.
fn eq_f64(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

/// Bitwise comparison of everything the algorithm (not the host clock)
/// produced — the `tests/sharded_determinism.rs` contract plus the
/// chaos telemetry: vanished/quarantined counts, shard retries and the
/// quorum fraction must also be invariant across topology and resume.
fn assert_bit_identical(a: &ExperimentResult, b: &ExperimentResult, ctx: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{ctx}: record count");
    for (x, y) in a.records.iter().zip(&b.records) {
        let rctx = format!("{ctx}: round {}", x.round);
        assert_eq!(x.round, y.round, "{rctx}");
        assert_eq!(x.cohort, y.cohort, "{rctx}: cohort");
        assert_eq!(x.straggler_ids, y.straggler_ids, "{rctx}: stragglers");
        assert_eq!(x.straggler_rates, y.straggler_rates, "{rctx}: rates");
        assert!(eq_f64(x.round_time, y.round_time), "{rctx}: round_time");
        assert!(eq_f64(x.vtime, y.vtime), "{rctx}: vtime");
        assert!(eq_f64(x.t_target, y.t_target), "{rctx}: t_target");
        assert!(
            eq_f64(x.straggler_time, y.straggler_time),
            "{rctx}: straggler_time"
        );
        assert!(eq_f64(x.train_loss, y.train_loss), "{rctx}: train_loss");
        assert!(eq_f64(x.train_acc, y.train_acc), "{rctx}: train_acc");
        assert!(eq_f64(x.test_loss, y.test_loss), "{rctx}: test_loss");
        assert!(eq_f64(x.test_acc, y.test_acc), "{rctx}: test_acc");
        assert!(
            eq_f64(x.invariant_fraction, y.invariant_fraction),
            "{rctx}: invariant_fraction"
        );
        assert_eq!(x.aggregated, y.aggregated, "{rctx}: aggregated");
        assert_eq!(x.dropped_updates, y.dropped_updates, "{rctx}: dropped");
        assert_eq!(x.stale_folded, y.stale_folded, "{rctx}: stale");
        assert_eq!(x.update_bytes, y.update_bytes, "{rctx}: update_bytes");
        assert_eq!(x.vanished, y.vanished, "{rctx}: vanished");
        assert_eq!(x.quarantined, y.quarantined, "{rctx}: quarantined");
        assert_eq!(x.shard_retries, y.shard_retries, "{rctx}: shard_retries");
        assert!(
            eq_f64(x.quorum_fraction, y.quorum_fraction),
            "{rctx}: quorum_fraction"
        );
    }
    assert!(eq_f64(a.final_test_acc, b.final_test_acc), "{ctx}");
    assert!(eq_f64(a.final_test_loss, b.final_test_loss), "{ctx}");
    assert!(eq_f64(a.total_vtime, b.total_vtime), "{ctx}");
    assert_eq!(a.seed, b.seed, "{ctx}");
}

/// The 2k storm fleet the sharded suite uses, with a chaos script bound.
fn chaos_cfg(spec: &str, seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::fleet("femnist_cnn", PolicyKind::Invariant, 2000, 64);
    cfg.rounds = 6;
    cfg.samples_per_client = 4;
    cfg.local_steps = 1;
    cfg.eval_every = 3;
    cfg.scenario = ScenarioConfig::parse("storm").unwrap();
    cfg.seed = seed;
    cfg.chaos = ChaosConfig::parse(spec).unwrap();
    cfg
}

/// Unique scratch directory for snapshot files; removed (best-effort) by
/// the tests that use it.
fn ckpt_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("fluid-chaos-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn snap_path(dir: &std::path::Path, round: usize) -> std::path::PathBuf {
    dir.join(format!("snap-{round:06}.fluidsnap"))
}

/// The headline pin: a full chaos storm (client vanish/hang/corrupt/NaN
/// plus shard crash/stall under a retry budget) replays bit-identically
/// — including every fault-telemetry field — at every `--shards` ∈
/// {1, 4} × `--threads` ∈ {1, 4}. Fault draws are pure data keyed by
/// `(round, client)` and shard events live in virtual slot space, so
/// topology can never be observable.
#[test]
fn chaos_storm_is_bit_identical_across_threads_and_shards() {
    let mut results = Vec::new();
    for shards in [1usize, 4] {
        for threads in [1usize, 4] {
            let mut cfg = chaos_cfg("storm", 20_260_729);
            cfg.shards = shards;
            cfg.threads = threads;
            cfg.shard_retry_max = 3; // crash events need 2 fires to recover
            cfg.quorum = 0.25; // exercised every round, never breached by storm rates
            results.push((shards, threads, coordinator::run_sim(&cfg).unwrap()));
        }
    }
    let (_, _, base) = &results[0];
    assert_eq!(base.records.len(), 6);
    // the storm actually happened: some client faults fired somewhere
    let faults: usize = base
        .records
        .iter()
        .map(|r| r.vanished + r.quarantined)
        .sum();
    assert!(faults > 0, "storm chaos drew no client faults at this seed");
    for (shards, threads, r) in &results[1..] {
        assert_bit_identical(base, r, &format!("shards={shards} threads={threads}"));
    }
}

/// A chaos script with every rate at zero is inert: the run is
/// bit-identical to `--chaos none` — on the plain executor and through
/// the sharded tree — because a zero-rate plan draws nothing and the
/// engine consumes no chaos randomness.
#[test]
fn zero_rate_chaos_is_inert() {
    for shards in [1usize, 2] {
        let mut plain = chaos_cfg("storm", 808);
        plain.chaos = None;
        plain.shards = shards;
        let control = coordinator::run_sim(&plain).unwrap();
        let mut zeroed = chaos_cfg("vanish:0.0", 808);
        zeroed.shards = shards;
        let run = coordinator::run_sim(&zeroed).unwrap();
        assert_bit_identical(&control, &run, &format!("zero-rate chaos, shards={shards}"));
        for r in &run.records {
            assert_eq!(r.vanished, 0);
            assert_eq!(r.quarantined, 0);
            assert_eq!(r.shard_retries, 0);
        }
    }
}

/// Checkpoint-under-chaos resumes bit-for-bit, and the QUAR section is
/// load-bearing: an aggressive corrupt script builds a non-empty
/// quarantine ledger whose bars shape later cohorts, so the resumed run
/// could only match the control if the ledger survived the snapshot.
#[test]
fn checkpoint_under_chaos_resumes_bit_for_bit_with_quarantine_ledger() {
    let control = coordinator::run_sim(&chaos_cfg("corrupt:0.2", 4411)).unwrap();
    let quarantined: usize = control.records.iter().map(|r| r.quarantined).sum();
    assert!(quarantined > 0, "corrupt:0.2 drew no quarantines at this seed");

    let dir = ckpt_dir("quar");
    let mut cfg = chaos_cfg("corrupt:0.2", 4411);
    cfg.checkpoint_every = 2;
    cfg.checkpoint_keep = cfg.rounds;
    cfg.checkpoint_dir = Some(dir.clone());
    let ckpt = coordinator::run_sim(&cfg).unwrap();
    assert_bit_identical(&control, &ckpt, "uninterrupted checkpointing run");

    // the mid-run snapshot carries the ledger
    let bytes = std::fs::read(snap_path(&dir, 4)).expect("snapshot at round 4");
    let snap = Snapshot::decode(&bytes).expect("snapshot decodes");
    assert!(
        !snap.quarantine.is_empty(),
        "0.2 corrupt over 4 rounds must quarantine someone"
    );

    // resume from both boundaries, including under a different thread
    // count — bars, strikes and decay anchors replay exactly
    for (at, threads) in [(2usize, 1usize), (4, 1), (4, 2)] {
        let mut rcfg = chaos_cfg("corrupt:0.2", 4411);
        rcfg.threads = threads;
        rcfg.resume_from = Some(snap_path(&dir, at));
        let resumed = coordinator::run_sim(&rcfg).unwrap();
        assert_bit_identical(
            &control,
            &resumed,
            &format!("resume@{at} threads={threads}"),
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The storm variant through the sharded tree: checkpoint under 4
/// shards with live shard-crash chaos, resume under 1 shard (and the
/// reverse) — the N→M rule holds under chaos because shard events are
/// drawn in slot space and recovery is bit-exact re-dispatch.
#[test]
fn storm_checkpoint_resumes_across_shard_counts() {
    let mut base = chaos_cfg("storm", 9177);
    base.shard_retry_max = 3;
    let control = coordinator::run_sim(&base).unwrap();

    let dir = ckpt_dir("storm");
    let mut cfg = base.clone();
    cfg.shards = 4;
    cfg.checkpoint_every = 2;
    cfg.checkpoint_keep = cfg.rounds;
    cfg.checkpoint_dir = Some(dir.clone());
    let four = coordinator::run_sim(&cfg).unwrap();
    assert_bit_identical(&control, &four, "uninterrupted 4-shard storm");

    for resume_shards in [1usize, 4] {
        let mut rcfg = base.clone();
        rcfg.shards = resume_shards;
        rcfg.resume_from = Some(snap_path(&dir, 4));
        let resumed = coordinator::run_sim(&rcfg).unwrap();
        assert_bit_identical(
            &control,
            &resumed,
            &format!("storm resume@4 under {resume_shards} shards"),
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A quorum breach is a typed [`QuorumFailed`] — never a panic, never a
/// silent half-round — raised before aggregation mutates state, so the
/// checkpoint it stopped at resumes cleanly under a relaxed floor and
/// completes bit-identically to an uninterrupted control.
#[test]
fn quorum_failure_is_typed_and_the_checkpoint_recovers() {
    // storm rates leave ~90% of a round fresh: a 0.3 floor always
    // passes, so the checkpointing run completes
    let mut cfg = chaos_cfg("storm", 5521);
    cfg.shard_retry_max = 3;
    cfg.quorum = 0.3;
    let dir = ckpt_dir("quorum");
    let mut ckpt = cfg.clone();
    ckpt.checkpoint_every = 2;
    ckpt.checkpoint_keep = ckpt.rounds;
    ckpt.checkpoint_dir = Some(dir.clone());
    let control = coordinator::run_sim(&ckpt).unwrap();

    // resume under a floor no storm round can meet: typed failure. The
    // quorum floor is an abort knob, not trajectory state, so the
    // fingerprint accepts the resume.
    let mut strict = cfg.clone();
    strict.quorum = 0.995;
    strict.resume_from = Some(snap_path(&dir, 2));
    let err = coordinator::run_sim(&strict).unwrap_err();
    let qf = err
        .downcast_ref::<QuorumFailed>()
        .unwrap_or_else(|| panic!("expected QuorumFailed, got: {err:#}"));
    assert!(qf.round >= 2, "resumed at round 2, failed at {}", qf.round);
    assert!(qf.arrived < qf.expected);
    assert!(eq_f64(qf.quorum, 0.995));
    assert!(format!("{qf}").contains("quorum failed at round"));

    // the checkpoint the failure stopped at is intact: relax the floor
    // and the same snapshot completes bit-identically to the control
    let mut relaxed = cfg.clone();
    relaxed.resume_from = Some(snap_path(&dir, 2));
    let resumed = coordinator::run_sim(&relaxed).unwrap();
    assert_bit_identical(&control, &resumed, "resume under relaxed quorum");
    let _ = std::fs::remove_dir_all(&dir);
}

/// An exhausted shard-retry budget surfaces as a typed [`ShardFault`]
/// with the faulting round attached; granting one more unit of budget
/// turns the same run into a clean completion. (A chaos `Crash` kills
/// the worker *and* its restart — two fires — so budget 1 exhausts.)
#[test]
fn exhausted_shard_retry_budget_is_a_typed_fault() {
    // crash-every-round: the headline "shards:<rate>" knob caps at
    // crash + stall <= 1, so pin the script directly
    let mut script = ChaosConfig::parse("shards").unwrap().unwrap();
    script.shard_crash = 1.0;
    script.shard_stall = 0.0;
    let mut cfg = chaos_cfg("none", 313);
    cfg.chaos = Some(script);
    cfg.rounds = 3;
    cfg.shards = 2;
    cfg.shard_retry_max = 1;
    let err = coordinator::run_sim(&cfg).unwrap_err();
    let fault = err
        .downcast_ref::<ShardFault>()
        .unwrap_or_else(|| panic!("expected ShardFault, got: {err:#}"));
    assert_eq!(fault.round, 0, "crash-every-round chaos fails immediately");

    cfg.shard_retry_max = 2;
    let run = coordinator::run_sim(&cfg).unwrap();
    assert_eq!(run.records.len(), 3);
    for r in &run.records {
        assert_eq!(r.shard_retries, 2, "round {}: crash costs two re-dispatches", r.round);
    }
}

/// Vanish telemetry: a heavy vanish script reports dropped participants
/// in every run's totals, those clients contribute no updates
/// (`aggregated` shrinks accordingly), and the run still completes —
/// graceful degradation, not an error.
#[test]
fn vanish_storms_degrade_gracefully() {
    let cfg = chaos_cfg("vanish:0.3", 2718);
    let run = coordinator::run_sim(&cfg).unwrap();
    let vanished: usize = run.records.iter().map(|r| r.vanished).sum();
    assert!(vanished > 0, "vanish:0.3 drew nothing at this seed");
    for r in &run.records {
        assert!(
            r.quorum_fraction >= 0.0 && r.quorum_fraction <= 1.0,
            "round {}: quorum fraction {} out of range",
            r.round,
            r.quorum_fraction
        );
    }
}
