//! Determinism suite for the fleet/sampler RNG plumbing.
//!
//! Runs entirely through `coordinator::run_sim` (the runtime-free
//! [`fluid::engine::SimExecutor`] backend), so it exercises the full
//! engine — fleet construction, cohort sampling, scenario churn, virtual
//! timing, barrier resolution, masked FedAvg — in *both* feature
//! configurations, with no artifacts and no PJRT.
//!
//! Pinned invariants:
//! * same seed ⇒ bit-identical `ExperimentResult` across 1/4/8 executor
//!   threads and across replays under every `--sync-mode`;
//! * different seeds ⇒ diverging sampled cohorts;
//! * a seeded 50k-client / sample-256 scenario with scripted churn runs
//!   to completion quickly and replays identical round metrics;
//! * only the sampled cohort is ever hydrated (peak resident data tracks
//!   the cohort, not the fleet).
//!
//! Wall-clock fields (`calibration_secs`, `train_wall_total`) measure the
//! host, not the algorithm, and are excluded from comparisons.

use fluid::coordinator::{self, ExperimentConfig, ExperimentResult};
use fluid::data::{shard_source_for_model, ShardSource, Split};
use fluid::dropout::PolicyKind;
use fluid::engine::{RoundEngine, ScenarioConfig, SimExecutor};
use fluid::fl::SamplerKind;
use fluid::model::sim_spec;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// NaN-aware bitwise equality.
fn eq_f64(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

/// Bitwise comparison of everything the algorithm (not the host clock)
/// produced.
fn assert_bit_identical(a: &ExperimentResult, b: &ExperimentResult, ctx: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{ctx}: record count");
    for (x, y) in a.records.iter().zip(&b.records) {
        let rctx = format!("{ctx}: round {}", x.round);
        assert_eq!(x.round, y.round, "{rctx}");
        assert_eq!(x.cohort, y.cohort, "{rctx}: cohort");
        assert_eq!(x.straggler_ids, y.straggler_ids, "{rctx}: stragglers");
        assert_eq!(x.straggler_rates, y.straggler_rates, "{rctx}: rates");
        assert!(eq_f64(x.round_time, y.round_time), "{rctx}: round_time");
        assert!(eq_f64(x.vtime, y.vtime), "{rctx}: vtime");
        assert!(eq_f64(x.t_target, y.t_target), "{rctx}: t_target");
        assert!(
            eq_f64(x.straggler_time, y.straggler_time),
            "{rctx}: straggler_time"
        );
        assert!(eq_f64(x.train_loss, y.train_loss), "{rctx}: train_loss");
        assert!(eq_f64(x.train_acc, y.train_acc), "{rctx}: train_acc");
        assert!(eq_f64(x.test_loss, y.test_loss), "{rctx}: test_loss");
        assert!(eq_f64(x.test_acc, y.test_acc), "{rctx}: test_acc");
        assert!(
            eq_f64(x.invariant_fraction, y.invariant_fraction),
            "{rctx}: invariant_fraction"
        );
        assert_eq!(x.aggregated, y.aggregated, "{rctx}: aggregated");
        assert_eq!(x.dropped_updates, y.dropped_updates, "{rctx}: dropped");
        assert_eq!(x.stale_folded, y.stale_folded, "{rctx}: stale");
    }
    assert!(eq_f64(a.final_test_acc, b.final_test_acc), "{ctx}");
    assert!(eq_f64(a.final_test_loss, b.final_test_loss), "{ctx}");
    assert!(eq_f64(a.total_vtime, b.total_vtime), "{ctx}");
    assert_eq!(a.seed, b.seed, "{ctx}");
}

fn fleet_cfg(seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::fleet("femnist_cnn", PolicyKind::Invariant, 2000, 64);
    cfg.rounds = 6;
    cfg.samples_per_client = 6;
    cfg.local_steps = 2;
    cfg.eval_every = 3;
    cfg.scenario = ScenarioConfig::parse("churn").unwrap();
    cfg.seed = seed;
    cfg
}

#[test]
fn same_seed_is_bit_identical_across_thread_counts() {
    let mut results = Vec::new();
    for threads in [1usize, 4, 8] {
        let mut cfg = fleet_cfg(42);
        cfg.threads = threads;
        results.push((threads, coordinator::run_sim(&cfg).unwrap()));
    }
    let (_, base) = &results[0];
    assert_eq!(base.records.len(), 6);
    for (threads, r) in &results[1..] {
        assert_bit_identical(base, r, &format!("threads={threads}"));
    }
}

#[test]
fn classic_path_is_thread_count_invariant_too() {
    // the non-fleet engine path through the sim backend: 12 clients,
    // fractional sampling, no scenario
    let mk = |threads| {
        let mut cfg = ExperimentConfig::scale("cifar_vgg9", PolicyKind::Invariant, 12);
        cfg.rounds = 5;
        cfg.samples_per_client = 6;
        cfg.local_steps = 1;
        cfg.sample_fraction = 0.5;
        cfg.eval_every = 2;
        cfg.threads = threads;
        coordinator::run_sim(&cfg).unwrap()
    };
    let a = mk(1);
    let b = mk(8);
    assert_bit_identical(&a, &b, "classic sim");
}

#[test]
fn every_sync_mode_replays_bit_identically() {
    use fluid::engine::SyncMode;
    for (name, mode) in [
        ("full", SyncMode::FullBarrier),
        ("deadline", SyncMode::Deadline { multiple_of_t_target: 1.25 }),
        ("buffered", SyncMode::Buffered { k: 48 }),
    ] {
        let mut cfg = fleet_cfg(7);
        cfg.sync_mode = mode;
        let a = coordinator::run_sim(&cfg).unwrap();
        let b = coordinator::run_sim(&cfg).unwrap();
        assert_bit_identical(&a, &b, name);
    }
}

#[test]
fn different_seeds_produce_diverging_cohorts() {
    let a = coordinator::run_sim(&fleet_cfg(1)).unwrap();
    let b = coordinator::run_sim(&fleet_cfg(2)).unwrap();
    let diverged = a
        .records
        .iter()
        .zip(&b.records)
        .any(|(x, y)| x.cohort != y.cohort);
    assert!(diverged, "seeds 1 and 2 sampled identical cohorts every round");
    // and each run's cohorts respect the configured size
    for r in a.records.iter().chain(&b.records) {
        assert!(r.cohort.len() <= 64, "round {}: cohort {}", r.round, r.cohort.len());
        assert!(!r.cohort.is_empty());
    }
}

#[test]
fn samplers_and_scenarios_replay_identically() {
    for sampler in [
        SamplerKind::Uniform,
        SamplerKind::WeightedByData,
        SamplerKind::AvailabilityAware,
    ] {
        for scenario in ["none", "drift", "storm"] {
            let mut cfg = fleet_cfg(11);
            cfg.rounds = 4;
            cfg.sampler = sampler;
            cfg.scenario = ScenarioConfig::parse(scenario).unwrap();
            let a = coordinator::run_sim(&cfg).unwrap();
            let b = coordinator::run_sim(&cfg).unwrap();
            assert_bit_identical(
                &a,
                &b,
                &format!("sampler={} scenario={scenario}", sampler.name()),
            );
        }
    }
}

/// The headline acceptance scenario: 50k clients, 256 sampled per round,
/// scripted churn — completes fast and replays bit-identically.
#[test]
fn fleet_50k_scenario_completes_and_replays() {
    let mut cfg = ExperimentConfig::fleet("femnist_cnn", PolicyKind::Invariant, 50_000, 256);
    // sized so even the debug-profile `cargo test` run sits far inside
    // the 60s budget on slow CI hardware (release is ~10x faster still)
    cfg.rounds = 6;
    cfg.samples_per_client = 4;
    cfg.local_steps = 1;
    cfg.eval_every = 3;
    cfg.scenario = ScenarioConfig::parse("storm").unwrap();
    cfg.seed = 20_260_729;

    let t0 = Instant::now();
    let a = coordinator::run_sim(&cfg).unwrap();
    let first_secs = t0.elapsed().as_secs_f64();
    assert!(
        first_secs < 60.0,
        "50k-client scenario took {first_secs:.1}s (budget 60s)"
    );
    assert_eq!(a.records.len(), 6);
    for r in &a.records {
        assert!(r.cohort.len() <= 256);
        assert!(r.cohort.iter().all(|&c| c < 50_000));
    }
    assert!(a.total_vtime > 0.0);
    assert!(a.final_test_acc.is_finite());

    let b = coordinator::run_sim(&cfg).unwrap();
    assert_bit_identical(&a, &b, "50k replay");
}

/// Shard source wrapper that counts hydrations and tracks the largest
/// number of simultaneously-live shards it ever handed out.
struct CountingSource {
    inner: Box<dyn ShardSource>,
    hydrated: Arc<AtomicUsize>,
}

impl ShardSource for CountingSource {
    fn num_shards(&self) -> usize {
        self.inner.num_shards()
    }
    fn shard_len(&self, shard: usize) -> usize {
        self.inner.shard_len(shard)
    }
    fn hydrate(&self, shard: usize) -> Split {
        self.hydrated.fetch_add(1, Ordering::SeqCst);
        self.inner.hydrate(shard)
    }
    fn test(&self) -> &Split {
        self.inner.test()
    }
    fn num_classes(&self) -> usize {
        self.inner.num_classes()
    }
}

#[test]
fn lazy_hydration_touches_only_the_sampled_cohort() {
    let mut cfg = ExperimentConfig::fleet("femnist_cnn", PolicyKind::None, 5_000, 32);
    cfg.rounds = 4;
    cfg.samples_per_client = 4;
    cfg.local_steps = 1;
    cfg.eval_every = cfg.rounds;

    let hydrated = Arc::new(AtomicUsize::new(0));
    let source = CountingSource {
        inner: shard_source_for_model("femnist_cnn", vec![4; 5_000], cfg.seed),
        hydrated: hydrated.clone(),
    };
    let engine = RoundEngine::with_shard_source(
        &cfg,
        SimExecutor::new(sim_spec("femnist_cnn"), 2),
        Box::new(source),
    )
    .unwrap();
    let res = engine.run().unwrap();

    let total: usize = res.records.iter().map(|r| r.cohort.len()).sum();
    let count = hydrated.load(Ordering::SeqCst);
    // every hydration belongs to a sampled participant; nothing close to
    // the 5k fleet is ever materialized
    assert!(count <= total, "hydrated {count} shards for {total} cohort slots");
    assert!(count > 0, "fleet round trained nobody");
    assert!(
        count <= cfg.rounds * 32,
        "hydration O(cohort) violated: {count}"
    );
}
