//! Determinism suite for the fleet/sampler RNG plumbing.
//!
//! Runs entirely through `coordinator::run_sim` (the runtime-free
//! [`fluid::engine::SimExecutor`] backend), so it exercises the full
//! engine — fleet construction, cohort sampling, scenario churn, virtual
//! timing, barrier resolution, masked FedAvg — in *both* feature
//! configurations, with no artifacts and no PJRT.
//!
//! Pinned invariants:
//! * same seed ⇒ bit-identical `ExperimentResult` across 1/4/8 executor
//!   threads and across replays under every `--sync-mode`;
//! * different seeds ⇒ diverging sampled cohorts;
//! * a seeded 50k-client / sample-256 scenario with scripted churn runs
//!   to completion quickly and replays identical round metrics;
//! * the same holds at **1M clients** (ISSUE 6): thread-count-invariant
//!   replay, a storm checkpoint/resume round-trip, and O(cohort)
//!   hydration through the streaming shard-size path;
//! * only the sampled cohort is ever hydrated (peak resident data tracks
//!   the cohort, not the fleet);
//! * **resume equivalence**: a run restored from a snapshot taken at any
//!   round boundary (first / mid / last-1, under every sync mode, and on
//!   a 2k-client storm fleet) reproduces the uninterrupted run's full
//!   history bit-for-bit, and corrupted/truncated snapshots fail with a
//!   clean error, never a panic.
//!
//! Wall-clock fields (`calibration_secs`, `train_wall_total`) measure the
//! host, not the algorithm, and are excluded from comparisons.

use fluid::coordinator::{self, ExperimentConfig, ExperimentResult};
use fluid::data::{shard_source_for_model, ShardSource, Split};
use fluid::dropout::PolicyKind;
use fluid::engine::{RoundEngine, ScenarioConfig, SimExecutor};
use fluid::fl::SamplerKind;
use fluid::model::sim_spec;
use fluid::straggler::AdaptMode;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// NaN-aware bitwise equality.
fn eq_f64(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

/// Bitwise comparison of everything the algorithm (not the host clock)
/// produced.
fn assert_bit_identical(a: &ExperimentResult, b: &ExperimentResult, ctx: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{ctx}: record count");
    for (x, y) in a.records.iter().zip(&b.records) {
        let rctx = format!("{ctx}: round {}", x.round);
        assert_eq!(x.round, y.round, "{rctx}");
        assert_eq!(x.cohort, y.cohort, "{rctx}: cohort");
        assert_eq!(x.straggler_ids, y.straggler_ids, "{rctx}: stragglers");
        assert_eq!(x.straggler_rates, y.straggler_rates, "{rctx}: rates");
        assert!(eq_f64(x.round_time, y.round_time), "{rctx}: round_time");
        assert!(eq_f64(x.vtime, y.vtime), "{rctx}: vtime");
        assert!(eq_f64(x.t_target, y.t_target), "{rctx}: t_target");
        assert!(
            eq_f64(x.straggler_time, y.straggler_time),
            "{rctx}: straggler_time"
        );
        assert!(eq_f64(x.train_loss, y.train_loss), "{rctx}: train_loss");
        assert!(eq_f64(x.train_acc, y.train_acc), "{rctx}: train_acc");
        assert!(eq_f64(x.test_loss, y.test_loss), "{rctx}: test_loss");
        assert!(eq_f64(x.test_acc, y.test_acc), "{rctx}: test_acc");
        assert!(
            eq_f64(x.invariant_fraction, y.invariant_fraction),
            "{rctx}: invariant_fraction"
        );
        assert_eq!(x.aggregated, y.aggregated, "{rctx}: aggregated");
        assert_eq!(x.dropped_updates, y.dropped_updates, "{rctx}: dropped");
        assert_eq!(x.stale_folded, y.stale_folded, "{rctx}: stale");
        assert!(
            eq_f64(x.straggler_wait, y.straggler_wait),
            "{rctx}: straggler_wait"
        );
        assert_eq!(x.admitted_stale, y.admitted_stale, "{rctx}: admitted_stale");
        assert!(eq_f64(x.soft_fraction, y.soft_fraction), "{rctx}: soft_fraction");
    }
    assert!(eq_f64(a.final_test_acc, b.final_test_acc), "{ctx}");
    assert!(eq_f64(a.final_test_loss, b.final_test_loss), "{ctx}");
    assert!(eq_f64(a.total_vtime, b.total_vtime), "{ctx}");
    assert_eq!(a.seed, b.seed, "{ctx}");
}

fn fleet_cfg(seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::fleet("femnist_cnn", PolicyKind::Invariant, 2000, 64);
    cfg.rounds = 6;
    cfg.samples_per_client = 6;
    cfg.local_steps = 2;
    cfg.eval_every = 3;
    cfg.scenario = ScenarioConfig::parse("churn").unwrap();
    cfg.seed = seed;
    cfg
}

#[test]
fn same_seed_is_bit_identical_across_thread_counts() {
    let mut results = Vec::new();
    for threads in [1usize, 4, 8] {
        let mut cfg = fleet_cfg(42);
        cfg.threads = threads;
        results.push((threads, coordinator::run_sim(&cfg).unwrap()));
    }
    let (_, base) = &results[0];
    assert_eq!(base.records.len(), 6);
    for (threads, r) in &results[1..] {
        assert_bit_identical(base, r, &format!("threads={threads}"));
    }
}

#[test]
fn classic_path_is_thread_count_invariant_too() {
    // the non-fleet engine path through the sim backend: 12 clients,
    // fractional sampling, no scenario
    let mk = |threads| {
        let mut cfg = ExperimentConfig::scale("cifar_vgg9", PolicyKind::Invariant, 12);
        cfg.rounds = 5;
        cfg.samples_per_client = 6;
        cfg.local_steps = 1;
        cfg.sample_fraction = 0.5;
        cfg.eval_every = 2;
        cfg.threads = threads;
        coordinator::run_sim(&cfg).unwrap()
    };
    let a = mk(1);
    let b = mk(8);
    assert_bit_identical(&a, &b, "classic sim");
}

#[test]
fn every_sync_mode_replays_bit_identically() {
    use fluid::engine::SyncMode;
    for (name, mode) in [
        ("full", SyncMode::FullBarrier),
        ("deadline", SyncMode::Deadline { multiple_of_t_target: 1.25 }),
        ("buffered", SyncMode::Buffered { k: 48 }),
    ] {
        let mut cfg = fleet_cfg(7);
        cfg.sync_mode = mode;
        let a = coordinator::run_sim(&cfg).unwrap();
        let b = coordinator::run_sim(&cfg).unwrap();
        assert_bit_identical(&a, &b, name);
    }
}

#[test]
fn different_seeds_produce_diverging_cohorts() {
    let a = coordinator::run_sim(&fleet_cfg(1)).unwrap();
    let b = coordinator::run_sim(&fleet_cfg(2)).unwrap();
    let diverged = a
        .records
        .iter()
        .zip(&b.records)
        .any(|(x, y)| x.cohort != y.cohort);
    assert!(diverged, "seeds 1 and 2 sampled identical cohorts every round");
    // and each run's cohorts respect the configured size
    for r in a.records.iter().chain(&b.records) {
        assert!(r.cohort.len() <= 64, "round {}: cohort {}", r.round, r.cohort.len());
        assert!(!r.cohort.is_empty());
    }
}

#[test]
fn samplers_and_scenarios_replay_identically() {
    for sampler in [
        SamplerKind::Uniform,
        SamplerKind::WeightedByData,
        SamplerKind::AvailabilityAware,
    ] {
        for scenario in ["none", "drift", "storm"] {
            let mut cfg = fleet_cfg(11);
            cfg.rounds = 4;
            cfg.sampler = sampler;
            cfg.scenario = ScenarioConfig::parse(scenario).unwrap();
            let a = coordinator::run_sim(&cfg).unwrap();
            let b = coordinator::run_sim(&cfg).unwrap();
            assert_bit_identical(
                &a,
                &b,
                &format!("sampler={} scenario={scenario}", sampler.name()),
            );
        }
    }
}

/// The headline acceptance scenario: 50k clients, 256 sampled per round,
/// scripted churn — completes fast and replays bit-identically.
#[test]
fn fleet_50k_scenario_completes_and_replays() {
    let mut cfg = ExperimentConfig::fleet("femnist_cnn", PolicyKind::Invariant, 50_000, 256);
    // sized so even the debug-profile `cargo test` run sits far inside
    // the 60s budget on slow CI hardware (release is ~10x faster still)
    cfg.rounds = 6;
    cfg.samples_per_client = 4;
    cfg.local_steps = 1;
    cfg.eval_every = 3;
    cfg.scenario = ScenarioConfig::parse("storm").unwrap();
    cfg.seed = 20_260_729;

    let t0 = Instant::now();
    let a = coordinator::run_sim(&cfg).unwrap();
    let first_secs = t0.elapsed().as_secs_f64();
    assert!(
        first_secs < 60.0,
        "50k-client scenario took {first_secs:.1}s (budget 60s)"
    );
    assert_eq!(a.records.len(), 6);
    for r in &a.records {
        assert!(r.cohort.len() <= 256);
        assert!(r.cohort.iter().all(|&c| c < 50_000));
    }
    assert!(a.total_vtime > 0.0);
    assert!(a.final_test_acc.is_finite());

    let b = coordinator::run_sim(&cfg).unwrap();
    assert_bit_identical(&a, &b, "50k replay");
}

/// The million-client leg (ISSUE 6): with incremental sampling and
/// delta churn a 1M-fleet round costs O(cohort + churn-delta), so a
/// short run completes inside a debug-profile test budget, and its full
/// history is bit-identical across server thread counts.
fn fleet_1m_cfg(seed: u64) -> ExperimentConfig {
    let mut cfg =
        ExperimentConfig::fleet("femnist_cnn", PolicyKind::Invariant, 1_000_000, 128);
    cfg.rounds = 2;
    cfg.samples_per_client = 2;
    cfg.local_steps = 1;
    cfg.eval_every = cfg.rounds;
    cfg.sampler = SamplerKind::AvailabilityAware;
    cfg.scenario = ScenarioConfig::parse("churn").unwrap();
    cfg.seed = seed;
    cfg
}

#[test]
fn fleet_1m_replays_bit_identically_across_thread_counts() {
    let mut results = Vec::new();
    for threads in [1usize, 4, 8] {
        let mut cfg = fleet_1m_cfg(1_000_003);
        cfg.threads = threads;
        let t0 = Instant::now();
        let r = coordinator::run_sim(&cfg).unwrap();
        let secs = t0.elapsed().as_secs_f64();
        assert!(
            secs < 120.0,
            "1M-client run (threads={threads}) took {secs:.1}s (budget 120s)"
        );
        results.push((threads, r));
    }
    let (_, base) = &results[0];
    assert_eq!(base.records.len(), 2);
    for r in &base.records {
        assert!(r.cohort.len() <= 128);
        assert!(r.cohort.iter().all(|&c| c < 1_000_000));
        assert!(!r.cohort.is_empty());
    }
    for (threads, r) in &results[1..] {
        assert_bit_identical(base, r, &format!("1m threads={threads}"));
    }
}

/// Checkpoint/resume round-trip at 1M under the full storm scenario:
/// the snapshot codec carries the 1M availability map and the resumed
/// run reproduces the uninterrupted control bit for bit.
#[test]
fn fleet_1m_storm_checkpoint_resume_round_trips() {
    let dir = ckpt_dir("storm1m");
    let mut cfg = fleet_1m_cfg(9_001);
    cfg.rounds = 3;
    cfg.scenario = ScenarioConfig::parse("storm").unwrap();
    cfg.checkpoint_every = 2;
    cfg.checkpoint_keep = 4;
    cfg.checkpoint_dir = Some(dir.clone());
    let control = coordinator::run_sim(&cfg).unwrap();
    assert_eq!(control.records.len(), 3);

    let mut rcfg = cfg.clone();
    rcfg.checkpoint_every = 0;
    rcfg.checkpoint_dir = None;
    rcfg.resume_from = Some(snap_path(&dir, 2));
    let resumed = coordinator::run_sim(&rcfg).unwrap();
    assert_bit_identical(&control, &resumed, "1m storm resume@2");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Full-observation drift fleet for the closed-loop acceptance test:
/// every client participates every round, so the controller (and the
/// paper baseline) see fresh measurements each recalibration.
fn drift_cfg(adapt: AdaptMode) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::fleet("femnist_cnn", PolicyKind::Invariant, 200, 200);
    cfg.rounds = 60;
    cfg.samples_per_client = 4;
    cfg.local_steps = 1;
    cfg.eval_every = cfg.rounds;
    cfg.straggler_fraction = 0.25;
    cfg.scenario = ScenarioConfig::parse("drift").unwrap();
    cfg.adapt = adapt;
    cfg.seed = 4242;
    cfg
}

/// Mean `straggler_time / t_target` over the last quarter of rounds —
/// how far the slowest assigned straggler lands from the target once
/// the final drift phase's adaptation has had its say.
fn last_quarter_miss(res: &ExperimentResult) -> f64 {
    let from = res.records.len() - res.records.len() / 4;
    let tail: Vec<f64> = res.records[from..]
        .iter()
        .filter(|r| r.t_target > 0.0 && r.straggler_time > 0.0)
        .map(|r| r.straggler_time / r.t_target)
        .collect();
    assert!(!tail.is_empty(), "no straggler measurements in the last quarter");
    tail.iter().sum::<f64>() / tail.len() as f64
}

/// The closed-loop acceptance criterion: under the drift scenario the
/// EWMA controller keeps the slowest straggler's arrival within 10% of
/// `T_target` over the last quarter of rounds, while the paper's static
/// menu (floored at r = 0.5, no feedback) misses by more than 25% — and
/// the adaptive run replays bit-identically.
#[test]
fn ewma_controller_tracks_t_target_under_drift() {
    let ewma = coordinator::run_sim(&drift_cfg(AdaptMode::Ewma)).unwrap();
    let paper = coordinator::run_sim(&drift_cfg(AdaptMode::Paper)).unwrap();

    let e = last_quarter_miss(&ewma);
    let p = last_quarter_miss(&paper);
    assert!(
        (e - 1.0).abs() <= 0.10,
        "ewma last-quarter straggler arrival is {e:.3}x T_target (want within 10%; paper {p:.3})"
    );
    assert!(
        p > 1.25,
        "static menu unexpectedly tracked T_target: {p:.3}x (ewma {e:.3}x)"
    );

    let replay = coordinator::run_sim(&drift_cfg(AdaptMode::Ewma)).unwrap();
    assert_bit_identical(&ewma, &replay, "ewma drift replay");
}

/// The controller's math is part of the thread-invariance contract.
#[test]
fn ewma_mode_is_thread_count_invariant() {
    let mk = |threads: usize| {
        let mut cfg = fleet_cfg(61);
        cfg.adapt = AdaptMode::Ewma;
        cfg.scenario = ScenarioConfig::parse("drift").unwrap();
        cfg.threads = threads;
        coordinator::run_sim(&cfg).unwrap()
    };
    let a = mk(1);
    let b = mk(8);
    assert_bit_identical(&a, &b, "ewma threads");
}

/// The straggler-membership bitmap drives the Exclude participant
/// filter at fleet scale; the path must replay bit-identically and
/// never aggregate an excluded straggler.
#[test]
fn exclude_policy_fleet_replays_bit_identically() {
    let mut cfg = ExperimentConfig::fleet("femnist_cnn", PolicyKind::Exclude, 2000, 64);
    cfg.rounds = 6;
    cfg.samples_per_client = 6;
    cfg.local_steps = 1;
    cfg.eval_every = 3;
    cfg.scenario = ScenarioConfig::parse("storm").unwrap();
    cfg.seed = 23;
    let a = coordinator::run_sim(&cfg).unwrap();
    let b = coordinator::run_sim(&cfg).unwrap();
    assert_bit_identical(&a, &b, "exclude fleet replay");
    for r in &a.records {
        assert!(r.aggregated <= r.cohort.len(), "round {}", r.round);
    }
}

/// Unique scratch directory for snapshot files; removed (best-effort) by
/// the tests that use it.
fn ckpt_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("fluid-determinism-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn snap_path(dir: &std::path::Path, round: usize) -> std::path::PathBuf {
    dir.join(format!("snap-{round:06}.fluidsnap"))
}

/// Resume equivalence across snapshot rounds 1 / mid / last-1 and all
/// three sync modes: the resumed run's history — snapshot prefix plus
/// freshly-executed suffix — must be bit-identical to the uninterrupted
/// control run.
#[test]
fn resume_is_bit_identical_at_any_boundary_and_sync_mode() {
    use fluid::engine::SyncMode;
    for (name, mode) in [
        ("full", SyncMode::FullBarrier),
        ("deadline", SyncMode::Deadline { multiple_of_t_target: 1.25 }),
        ("buffered", SyncMode::Buffered { k: 48 }),
    ] {
        let dir = ckpt_dir(&format!("mode-{name}"));
        let mut cfg = fleet_cfg(33);
        cfg.sync_mode = mode;
        cfg.checkpoint_every = 1; // a snapshot at every round boundary
        cfg.checkpoint_keep = cfg.rounds;
        cfg.checkpoint_dir = Some(dir.clone());
        let control = coordinator::run_sim(&cfg).unwrap();
        assert_eq!(control.records.len(), cfg.rounds);
        for k in [1usize, cfg.rounds / 2, cfg.rounds - 1] {
            let mut rcfg = fleet_cfg(33);
            rcfg.sync_mode = mode;
            rcfg.resume_from = Some(snap_path(&dir, k));
            let resumed = coordinator::run_sim(&rcfg).unwrap();
            assert_bit_identical(&control, &resumed, &format!("{name} resume@{k}"));
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// The acceptance fleet: 2k clients under the full storm scenario
/// (churn + drift + jitter) with availability-aware sampling. Resume
/// from a mid-run snapshot and from the rotated latest via directory
/// resolution; both must match the control bit-for-bit.
#[test]
fn storm_fleet_resume_matches_uninterrupted_run() {
    let dir = ckpt_dir("storm2k");
    let mut cfg = ExperimentConfig::fleet("femnist_cnn", PolicyKind::Invariant, 2000, 64);
    cfg.rounds = 10;
    cfg.samples_per_client = 4;
    cfg.local_steps = 1;
    cfg.eval_every = 4;
    cfg.sampler = SamplerKind::AvailabilityAware;
    cfg.scenario = ScenarioConfig::parse("storm").unwrap();
    cfg.seed = 77;
    cfg.checkpoint_every = 3;
    cfg.checkpoint_keep = 16;
    cfg.checkpoint_dir = Some(dir.clone());
    let control = coordinator::run_sim(&cfg).unwrap();

    let mut mid = cfg.clone();
    mid.checkpoint_every = 0;
    mid.checkpoint_dir = None;
    mid.resume_from = Some(snap_path(&dir, 6));
    let resumed_mid = coordinator::run_sim(&mid).unwrap();
    assert_bit_identical(&control, &resumed_mid, "storm resume@6");

    // a directory --resume resolves to the newest snapshot (round 9)
    let mut latest = mid.clone();
    latest.resume_from = Some(dir.clone());
    let resumed_latest = coordinator::run_sim(&latest).unwrap();
    assert_bit_identical(&control, &resumed_latest, "storm resume@latest");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A snapshot only resumes the experiment it was taken from: any change
/// to a semantic config field is rejected up front.
#[test]
fn resume_rejects_a_mismatched_config() {
    let dir = ckpt_dir("fingerprint");
    let mut cfg = fleet_cfg(5);
    cfg.checkpoint_every = 2;
    cfg.checkpoint_dir = Some(dir.clone());
    coordinator::run_sim(&cfg).unwrap();

    let mut other = fleet_cfg(6); // different seed => different fingerprint
    other.resume_from = Some(snap_path(&dir, 2));
    let err = format!("{:#}", coordinator::run_sim(&other).unwrap_err());
    assert!(err.contains("different experiment configuration"), "{err}");

    // threads are a non-semantic knob: resuming under a different thread
    // count is allowed and still bit-identical
    let control = {
        let cfg = fleet_cfg(5);
        coordinator::run_sim(&cfg).unwrap()
    };
    let mut threaded = fleet_cfg(5);
    threaded.threads = 7;
    threaded.resume_from = Some(snap_path(&dir, 2));
    let resumed = coordinator::run_sim(&threaded).unwrap();
    assert_bit_identical(&control, &resumed, "thread-count change across resume");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Snapshot/resume equivalence is unaffected by `--threads`: a control
/// run at one thread checkpoints, and runs resumed from that snapshot
/// under 2/4/8 server threads — parallel aggregation, fused observe and
/// all — reproduce the control bit for bit, as do uninterrupted runs at
/// those thread counts.
#[test]
fn resume_equivalence_is_thread_count_invariant() {
    let dir = ckpt_dir("threads");
    let mut cfg = fleet_cfg(55);
    cfg.threads = 1;
    cfg.checkpoint_every = 2;
    cfg.checkpoint_keep = cfg.rounds;
    cfg.checkpoint_dir = Some(dir.clone());
    let control = coordinator::run_sim(&cfg).unwrap();
    for threads in [2usize, 4, 8] {
        let mut rcfg = fleet_cfg(55);
        rcfg.threads = threads;
        rcfg.resume_from = Some(snap_path(&dir, 2));
        let resumed = coordinator::run_sim(&rcfg).unwrap();
        assert_bit_identical(&control, &resumed, &format!("resume threads={threads}"));
        let mut fcfg = fleet_cfg(55);
        fcfg.threads = threads;
        let fresh = coordinator::run_sim(&fcfg).unwrap();
        assert_bit_identical(&control, &fresh, &format!("fresh threads={threads}"));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Controller state lives in the snapshot `CTRL` section: an ewma run
/// resumed from any boundary is bit-identical to the uninterrupted run,
/// and a snapshot stripped of its controller state (what a
/// pre-controller writer would have produced) still resumes cleanly.
#[test]
fn ewma_resume_is_bit_identical_and_old_snapshots_still_resume() {
    let dir = ckpt_dir("adapt");
    let mut cfg = fleet_cfg(99);
    cfg.adapt = AdaptMode::Ewma;
    cfg.scenario = ScenarioConfig::parse("drift").unwrap();
    cfg.checkpoint_every = 2;
    cfg.checkpoint_keep = cfg.rounds;
    cfg.checkpoint_dir = Some(dir.clone());
    let control = coordinator::run_sim(&cfg).unwrap();

    let mut rcfg = cfg.clone();
    rcfg.checkpoint_every = 0;
    rcfg.checkpoint_dir = None;
    for k in [2usize, 4] {
        let mut r = rcfg.clone();
        r.resume_from = Some(snap_path(&dir, k));
        let resumed = coordinator::run_sim(&r).unwrap();
        assert_bit_identical(&control, &resumed, &format!("ewma resume@{k}"));
    }

    // a paper-mode snapshot must not resume an ewma config (the adapt
    // knobs are part of the semantic fingerprint)
    let mut paper = cfg.clone();
    paper.adapt = AdaptMode::Paper;
    paper.checkpoint_every = 0;
    paper.checkpoint_dir = None;
    paper.resume_from = Some(snap_path(&dir, 2));
    let err = format!("{:#}", coordinator::run_sim(&paper).unwrap_err());
    assert!(err.contains("different experiment configuration"), "{err}");

    // simulate an old-writer snapshot: strip the CTRL payload and
    // re-encode — the resumed run starts its controller fresh but must
    // still complete every remaining round
    let mut snap = fluid::snapshot::SnapshotStore::load_file(&snap_path(&dir, 4)).unwrap();
    assert!(snap.ctrl.is_some(), "ewma snapshot must carry controller state");
    snap.ctrl = None;
    let old = dir.join("old-writer.fluidsnap");
    std::fs::write(&old, snap.encode()).unwrap();
    let mut ocfg = rcfg.clone();
    ocfg.resume_from = Some(old);
    let resumed_old = coordinator::run_sim(&ocfg).unwrap();
    assert_eq!(resumed_old.records.len(), cfg.rounds);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Corrupted and truncated snapshots must surface as clean errors from
/// `run_sim`, never a panic or a silently-wrong resume.
#[test]
fn corrupted_or_truncated_snapshot_errors_cleanly() {
    let dir = ckpt_dir("corrupt");
    let mut cfg = fleet_cfg(8);
    cfg.checkpoint_every = 2;
    cfg.checkpoint_dir = Some(dir.clone());
    coordinator::run_sim(&cfg).unwrap();
    let good = std::fs::read(snap_path(&dir, 2)).unwrap();

    // flip one bit mid-payload: the checksum must catch it
    let mut corrupt = good.clone();
    let mid = corrupt.len() / 2;
    corrupt[mid] ^= 0x40;
    let corrupt_path = dir.join("corrupt.fluidsnap");
    std::fs::write(&corrupt_path, &corrupt).unwrap();
    let mut rcfg = fleet_cfg(8);
    rcfg.resume_from = Some(corrupt_path);
    let err = format!("{:#}", coordinator::run_sim(&rcfg).unwrap_err());
    assert!(
        err.contains("checksum") || err.contains("corrupted"),
        "unexpected corruption error: {err}"
    );

    // truncate the file: the header length check must catch it
    let trunc_path = dir.join("trunc.fluidsnap");
    std::fs::write(&trunc_path, &good[..good.len() / 3]).unwrap();
    let mut tcfg = fleet_cfg(8);
    tcfg.resume_from = Some(trunc_path);
    assert!(coordinator::run_sim(&tcfg).is_err());

    // and decode itself never panics on any truncation prefix
    for cut in (0..good.len()).step_by(97) {
        assert!(fluid::snapshot::Snapshot::decode(&good[..cut]).is_err());
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A checksum-valid but semantically-inconsistent snapshot (ids outside
/// the population, misaligned detection tables) is rejected by
/// `restore`'s validation instead of panicking rounds later.
#[test]
fn semantically_invalid_snapshot_is_rejected() {
    let dir = ckpt_dir("semantic");
    let mut cfg = fleet_cfg(31);
    cfg.checkpoint_every = 2;
    cfg.checkpoint_dir = Some(dir.clone());
    coordinator::run_sim(&cfg).unwrap();

    let mut snap = fluid::snapshot::SnapshotStore::load_file(&snap_path(&dir, 2)).unwrap();
    snap.detection = Some(fluid::straggler::Detection {
        stragglers: vec![5000], // fleet has 2000 clients
        t_target: 1.0,
        speedups: vec![1.5],
        rates: vec![0.75],
    });
    let bad = dir.join("bad.fluidsnap");
    std::fs::write(&bad, snap.encode()).unwrap();
    let mut rcfg = fleet_cfg(31);
    rcfg.resume_from = Some(bad);
    let err = format!("{:#}", coordinator::run_sim(&rcfg).unwrap_err());
    assert!(err.contains("outside the"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// `crash_after` fault injection surfaces as a marker error (the engine
/// never kills the process), fires only after the due checkpoint was
/// written, and the resumed run matches an uninterrupted control.
#[test]
fn injected_crash_checkpoints_then_resumes_bit_identically() {
    let dir = ckpt_dir("crash");
    let mut cfg = fleet_cfg(21);
    cfg.checkpoint_every = 2;
    cfg.checkpoint_dir = Some(dir.clone());
    cfg.crash_after = Some(4);
    let err = coordinator::run_sim(&cfg).unwrap_err();
    assert!(
        err.downcast_ref::<fluid::engine::FaultInjected>().is_some(),
        "expected FaultInjected, got: {err:#}"
    );
    assert!(snap_path(&dir, 4).exists(), "due checkpoint missing at crash");

    let control = {
        let cfg = fleet_cfg(21);
        coordinator::run_sim(&cfg).unwrap()
    };
    let mut rcfg = fleet_cfg(21);
    rcfg.resume_from = Some(dir.clone());
    let resumed = coordinator::run_sim(&rcfg).unwrap();
    assert_bit_identical(&control, &resumed, "resume after injected crash");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Checkpoint rotation keeps only the newest N snapshots.
#[test]
fn checkpoint_rotation_keeps_last_n() {
    let dir = ckpt_dir("rotate");
    let mut cfg = fleet_cfg(13);
    cfg.checkpoint_every = 1;
    cfg.checkpoint_keep = 2;
    cfg.checkpoint_dir = Some(dir.clone());
    coordinator::run_sim(&cfg).unwrap();
    let mut names: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    names.sort();
    assert_eq!(
        names,
        vec!["snap-000005.fluidsnap".to_string(), "snap-000006.fluidsnap".to_string()],
        "6-round run with keep=2"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- policy zoo (ISSUE 10) --------------------------------------------------

/// A 2k-client storm fleet running one of the zoo mitigations. SAFA gets
/// the Buffered sync mode its admission logic rides on (k = 48 of a
/// 64-cohort forces ~16 buffered stragglers per round); FedProx gets a
/// non-trivial λ so the elastic blend actually executes.
fn zoo_cfg(mit: fluid::policy::Mitigation, seed: u64) -> ExperimentConfig {
    use fluid::policy::Mitigation;
    let mut cfg = ExperimentConfig::fleet("femnist_cnn", PolicyKind::None, 2000, 64);
    cfg.rounds = 6;
    cfg.samples_per_client = 4;
    cfg.local_steps = 2;
    cfg.eval_every = 3;
    cfg.scenario = ScenarioConfig::parse("storm").unwrap();
    cfg.seed = seed;
    cfg.mitigation = mit;
    match mit {
        Mitigation::FedProx => cfg.mitigation_trade_off = 0.5,
        Mitigation::Safa => cfg.sync_mode = fluid::engine::SyncMode::Buffered { k: 48 },
        _ => {}
    }
    cfg.validate().unwrap();
    cfg
}

/// Full-observation drift fleet for the zoo behavior assertions: every
/// client participates every round, so detected stragglers are always in
/// the cohort and the mitigation visibly acts each round.
fn zoo_dense_cfg(mit: fluid::policy::Mitigation, seed: u64) -> ExperimentConfig {
    let mut cfg = zoo_cfg(mit, seed);
    cfg.fleet_size = Some(200);
    cfg.sample_k = 200;
    cfg.straggler_fraction = 0.25;
    cfg.scenario = ScenarioConfig::parse("drift").unwrap();
    if let fluid::engine::SyncMode::Buffered { .. } = cfg.sync_mode {
        cfg.sync_mode = fluid::engine::SyncMode::Buffered { k: 160 };
    }
    cfg.validate().unwrap();
    cfg
}

/// Every zoo mitigation is part of the thread- and shard-invariance
/// contract: the storm-fleet history is bit-identical at any executor
/// thread count and any aggregator shard count.
#[test]
fn zoo_policies_are_thread_and_shard_invariant() {
    use fluid::policy::Mitigation;
    for mit in [Mitigation::FedProx, Mitigation::Safa, Mitigation::Helios] {
        let base = {
            let mut cfg = zoo_cfg(mit, 19);
            cfg.threads = 1;
            coordinator::run_sim(&cfg).unwrap()
        };
        let threaded = {
            let mut cfg = zoo_cfg(mit, 19);
            cfg.threads = 8;
            coordinator::run_sim(&cfg).unwrap()
        };
        assert_bit_identical(&base, &threaded, &format!("{} threads=8", mit.name()));
        let sharded = {
            let mut cfg = zoo_cfg(mit, 19);
            cfg.shards = 3;
            coordinator::run_sim(&cfg).unwrap()
        };
        assert_bit_identical(&base, &sharded, &format!("{} shards=3", mit.name()));
    }
}

/// Kill/resume for the zoo: the ZOO snapshot section round-trips each
/// mitigation's per-client state (SAFA versions, Helios fractions), so a
/// resumed run matches the uninterrupted control bit for bit — and a
/// pre-zoo snapshot (no ZOO section) still resumes cleanly with fresh
/// zoo state.
#[test]
fn zoo_resume_is_bit_identical_and_pre_zoo_snapshots_still_resume() {
    use fluid::policy::Mitigation;
    for mit in [Mitigation::FedProx, Mitigation::Safa, Mitigation::Helios] {
        let dir = ckpt_dir(&format!("zoo-{}", mit.name()));
        let mut cfg = zoo_cfg(mit, 91);
        cfg.checkpoint_every = 2;
        cfg.checkpoint_keep = cfg.rounds;
        cfg.checkpoint_dir = Some(dir.clone());
        let control = coordinator::run_sim(&cfg).unwrap();
        assert_eq!(control.records.len(), cfg.rounds);

        let mut rcfg = cfg.clone();
        rcfg.checkpoint_every = 0;
        rcfg.checkpoint_dir = None;
        for k in [2usize, 4] {
            let mut r = rcfg.clone();
            r.resume_from = Some(snap_path(&dir, k));
            let resumed = coordinator::run_sim(&r).unwrap();
            assert_bit_identical(&control, &resumed, &format!("{} resume@{k}", mit.name()));
        }

        // simulate an old-writer snapshot: strip the ZOO payload and
        // re-encode — SAFA restarts its version ledger, Helios its
        // fraction table, and the run still completes every round
        let mut snap = fluid::snapshot::SnapshotStore::load_file(&snap_path(&dir, 4)).unwrap();
        if mit != Mitigation::FedProx {
            assert!(snap.zoo.is_some(), "{} snapshot must carry zoo state", mit.name());
        }
        snap.zoo = None;
        let old = dir.join("pre-zoo.fluidsnap");
        std::fs::write(&old, snap.encode()).unwrap();
        let mut ocfg = rcfg.clone();
        ocfg.resume_from = Some(old);
        let resumed_old = coordinator::run_sim(&ocfg).unwrap();
        assert_eq!(resumed_old.records.len(), cfg.rounds, "{}", mit.name());
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// The FedProx identity pin: λ = 1 turns the elastic blend into plain
/// FedAvg, so a fedprox run at λ = 1 must be bit-identical to the `none`
/// baseline under the fluid mitigation — the seam's zero-cost contract.
#[test]
fn fedprox_at_lambda_one_matches_the_none_baseline() {
    use fluid::policy::Mitigation;
    let mut prox = zoo_cfg(Mitigation::FedProx, 71);
    prox.mitigation_trade_off = 1.0;
    let baseline = {
        let cfg = zoo_cfg(Mitigation::Fluid, 71);
        coordinator::run_sim(&cfg).unwrap()
    };
    let elastic = coordinator::run_sim(&prox).unwrap();
    assert_bit_identical(&baseline, &elastic, "fedprox λ=1 vs none");
}

/// The zoo behaviors are observable in the per-round metrics: Helios
/// reduces the mean soft-training fraction below 1.0 once stragglers are
/// detected, and SAFA's lag-tolerant admission folds stale updates back
/// into later aggregations.
#[test]
fn helios_softens_training_and_safa_folds_stale_updates() {
    use fluid::policy::Mitigation;
    let helios = coordinator::run_sim(&zoo_dense_cfg(Mitigation::Helios, 13)).unwrap();
    for r in &helios.records {
        assert!(
            r.soft_fraction > 0.0 && r.soft_fraction <= 1.0,
            "round {}: soft_fraction {}",
            r.round,
            r.soft_fraction
        );
    }
    assert!(
        helios.records.iter().any(|r| r.soft_fraction < 1.0),
        "helios never scheduled a reduced local epoch"
    );

    let safa = coordinator::run_sim(&zoo_dense_cfg(Mitigation::Safa, 13)).unwrap();
    let admitted: usize = safa.records.iter().map(|r| r.admitted_stale).sum();
    assert!(admitted > 0, "buffered drift run never admitted a stale update");
    // fluid's full barrier on the same fleet admits none
    let fluid_run = coordinator::run_sim(&zoo_dense_cfg(Mitigation::Fluid, 13)).unwrap();
    let admitted_fluid: usize = fluid_run.records.iter().map(|r| r.admitted_stale).sum();
    assert_eq!(admitted_fluid, 0);
}

/// Shard source wrapper that counts hydrations and tracks the largest
/// number of simultaneously-live shards it ever handed out.
struct CountingSource {
    inner: Box<dyn ShardSource>,
    hydrated: Arc<AtomicUsize>,
}

impl ShardSource for CountingSource {
    fn num_shards(&self) -> usize {
        self.inner.num_shards()
    }
    fn shard_len(&self, shard: usize) -> usize {
        self.inner.shard_len(shard)
    }
    fn hydrate(&self, shard: usize) -> Split {
        self.hydrated.fetch_add(1, Ordering::SeqCst);
        self.inner.hydrate(shard)
    }
    fn test(&self) -> &Split {
        self.inner.test()
    }
    fn num_classes(&self) -> usize {
        self.inner.num_classes()
    }
}

#[test]
fn lazy_hydration_touches_only_the_sampled_cohort() {
    let mut cfg = ExperimentConfig::fleet("femnist_cnn", PolicyKind::None, 5_000, 32);
    cfg.rounds = 4;
    cfg.samples_per_client = 4;
    cfg.local_steps = 1;
    cfg.eval_every = cfg.rounds;

    let hydrated = Arc::new(AtomicUsize::new(0));
    let source = CountingSource {
        inner: shard_source_for_model("femnist_cnn", vec![4; 5_000], cfg.seed),
        hydrated: hydrated.clone(),
    };
    let engine = RoundEngine::with_shard_source(
        &cfg,
        SimExecutor::new(sim_spec("femnist_cnn"), 2),
        Box::new(source),
    )
    .unwrap();
    let res = engine.run().unwrap();

    let total: usize = res.records.iter().map(|r| r.cohort.len()).sum();
    let count = hydrated.load(Ordering::SeqCst);
    // every hydration belongs to a sampled participant; nothing close to
    // the 5k fleet is ever materialized
    assert!(count <= total, "hydrated {count} shards for {total} cohort slots");
    assert!(count > 0, "fleet round trained nobody");
    assert!(
        count <= cfg.rounds * 32,
        "hydration O(cohort) violated: {count}"
    );
}

/// The 1M counterpart, through the *streaming* shard-size path: the
/// source's descriptor memory is a few words (no 1M size table), and a
/// run still hydrates only the sampled cohort's shards.
#[test]
fn fleet_1m_hydration_stays_o_cohort_with_streaming_sizes() {
    use fluid::data::ShardSizes;
    let mut cfg = ExperimentConfig::fleet("femnist_cnn", PolicyKind::None, 1_000_000, 64);
    cfg.rounds = 2;
    cfg.samples_per_client = 2;
    cfg.local_steps = 1;
    cfg.eval_every = cfg.rounds;

    let hydrated = Arc::new(AtomicUsize::new(0));
    let source = CountingSource {
        inner: shard_source_for_model(
            "femnist_cnn",
            ShardSizes::lognormal(1_000_000, cfg.samples_per_client, 0.45, cfg.seed),
            cfg.seed,
        ),
        hydrated: hydrated.clone(),
    };
    let engine = RoundEngine::with_shard_source(
        &cfg,
        SimExecutor::new(sim_spec("femnist_cnn"), 2),
        Box::new(source),
    )
    .unwrap();
    let res = engine.run().unwrap();

    let total: usize = res.records.iter().map(|r| r.cohort.len()).sum();
    let count = hydrated.load(Ordering::SeqCst);
    assert!(count <= total, "hydrated {count} shards for {total} cohort slots");
    assert!(count > 0, "1M fleet round trained nobody");
    assert!(
        count <= cfg.rounds * 64,
        "hydration O(cohort) violated at 1M: {count}"
    );
}
