//! Integration: real artifacts end-to-end through the PJRT runtime.
//!
//! These tests require `make artifacts` to have run; they skip (with a
//! note) otherwise so `cargo test` stays green on a fresh checkout.

use fluid::runtime::{Batch, Session, XData};
use fluid::tensor::Tensor;
use fluid::util::prng::Pcg32;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have(model: &str) -> bool {
    // without the xla feature the runtime is a stub: Session::new always
    // fails, so artifact presence alone is not enough to run
    cfg!(feature = "xla")
        && artifacts_dir()
            .join(format!("{model}_manifest.json"))
            .exists()
}

fn random_batch(spec: &fluid::model::ModelSpec, seed: u64) -> Batch {
    let mut rng = Pcg32::new(seed, 99);
    let n: usize = spec.x_shape.iter().product();
    let x = if spec.x_is_int {
        XData::I32((0..n).map(|_| rng.below(80) as i32).collect())
    } else {
        XData::F32(Tensor::from_vec(
            &spec.x_shape,
            (0..n).map(|_| rng.next_f32()).collect(),
        ))
    };
    let y = (0..spec.batch_size)
        .map(|_| rng.below(spec.num_classes as u32) as i32)
        .collect();
    Batch { x, y }
}

#[test]
fn stub_runtime_reports_missing_feature_cleanly() {
    // the one case that *runs* under --no-default-features: the stub
    // session must refuse construction with an actionable message
    // instead of panicking or silently succeeding
    if cfg!(feature = "xla") {
        return;
    }
    let err = match Session::new(artifacts_dir()) {
        Ok(_) => panic!("stub Session::new must fail"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("xla"), "unhelpful stub error: {err}");
}

#[test]
fn femnist_train_loss_decreases() {
    if !have("femnist_cnn") {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let sess = Session::new(artifacts_dir()).unwrap();
    let runner = sess.runner("femnist_cnn").unwrap();
    let mut params = runner.spec.init_params(42);
    let masks = runner.full_masks();
    let batch = random_batch(&runner.spec, 7);

    let first = runner.train_step(&params, &masks, &batch, 0.01).unwrap();
    params = first.params;
    let mut last = first.loss;
    for _ in 0..10 {
        let out = runner.train_step(&params, &masks, &batch, 0.01).unwrap();
        params = out.params;
        last = out.loss;
    }
    assert!(last.is_finite());
    assert!(
        last < first.loss,
        "loss did not decrease: {} -> {last}",
        first.loss
    );
}

#[test]
fn masked_neurons_do_not_update_via_runtime() {
    if !have("femnist_cnn") {
        return;
    }
    let sess = Session::new(artifacts_dir()).unwrap();
    let runner = sess.runner("femnist_cnn").unwrap();
    let params = runner.spec.init_params(1);
    // mask out the first half of fc1 (mask index 2)
    let mut masks = runner.full_masks();
    let n = masks[2].len();
    for i in 0..n / 2 {
        masks[2].data_mut()[i] = 0.0;
    }
    let batch = random_batch(&runner.spec, 3);
    let out = runner.train_step(&params, &masks, &batch, 0.1).unwrap();

    // fc1_w is params[4] with shape [3136, 120]; dropped columns unchanged
    let (fan_in, neurons) = params[4].as_2d_neurons();
    assert_eq!(neurons, n);
    let old = params[4].data();
    let new = out.params[4].data();
    for r in 0..fan_in {
        for c in 0..n / 2 {
            assert_eq!(old[r * neurons + c], new[r * neurons + c]);
        }
    }
    // and some kept column moved
    let mut any_moved = false;
    for r in 0..fan_in {
        for c in n / 2..n {
            if old[r * neurons + c] != new[r * neurons + c] {
                any_moved = true;
            }
        }
    }
    assert!(any_moved);
}

#[test]
fn delta_step_matches_host_computation() {
    if !have("femnist_cnn") {
        return;
    }
    let sess = Session::new(artifacts_dir()).unwrap();
    let runner = sess.runner("femnist_cnn").unwrap();
    let old = runner.spec.init_params(5);
    let masks = runner.full_masks();
    let batch = random_batch(&runner.spec, 11);
    let new = runner.train_step(&old, &masks, &batch, 0.05).unwrap().params;

    let deltas = runner.delta_step(&old, &new).unwrap();
    assert_eq!(deltas.len(), runner.spec.masks.len());

    // host recomputation for the fc1 group (params[4], delta index 2)
    let (fan_in, neurons) = old[4].as_2d_neurons();
    let mut want = vec![0.0f32; neurons];
    for r in 0..fan_in {
        for c in 0..neurons {
            let o = old[4].data()[r * neurons + c];
            let nw = new[4].data()[r * neurons + c];
            let rel = (nw - o).abs() / (o.abs() + 1e-8);
            if rel > want[c] {
                want[c] = rel;
            }
        }
    }
    let got = deltas[2].data();
    for c in 0..neurons {
        assert!(
            (got[c] - want[c]).abs() <= 1e-5 * (1.0 + want[c].abs()),
            "neuron {c}: got {} want {}",
            got[c],
            want[c]
        );
    }
}

#[test]
fn eval_step_counts_are_sane() {
    if !have("femnist_cnn") {
        return;
    }
    let sess = Session::new(artifacts_dir()).unwrap();
    let runner = sess.runner("femnist_cnn").unwrap();
    let params = runner.spec.init_params(8);
    let masks = runner.full_masks();
    let batch = random_batch(&runner.spec, 13);
    let out = runner.eval_step(&params, &masks, &batch).unwrap();
    assert!(out.loss.is_finite() && out.loss > 0.0);
    assert!(out.correct >= 0.0 && out.correct <= runner.spec.batch_size as f32);
}

#[test]
fn lstm_int_input_path() {
    if !have("shakespeare_lstm") {
        return;
    }
    let sess = Session::new(artifacts_dir()).unwrap();
    let runner = sess.runner("shakespeare_lstm").unwrap();
    let params = runner.spec.init_params(21);
    let masks = runner.full_masks();
    let batch = random_batch(&runner.spec, 17);
    let out = runner.train_step(&params, &masks, &batch, 0.01).unwrap();
    assert!(out.loss.is_finite());
    assert_eq!(out.params.len(), runner.spec.params.len());
}

#[test]
fn shape_mismatch_rejected() {
    if !have("femnist_cnn") {
        return;
    }
    let sess = Session::new(artifacts_dir()).unwrap();
    let runner = sess.runner("femnist_cnn").unwrap();
    let mut params = runner.spec.init_params(2);
    params[0] = Tensor::zeros(&[1, 1, 1, 1]); // wrong shape
    let masks = runner.full_masks();
    let batch = random_batch(&runner.spec, 1);
    assert!(runner.train_step(&params, &masks, &batch, 0.01).is_err());
}

#[test]
fn parallel_exec_stress() {
    // validates the Send/Sync claims in runtime::step — many threads
    // sharing one compiled executable.
    if !have("femnist_cnn") {
        return;
    }
    let sess = Session::new(artifacts_dir()).unwrap();
    let runner = std::sync::Arc::new(sess.runner("femnist_cnn").unwrap());
    let params = std::sync::Arc::new(runner.spec.init_params(3));
    let masks = std::sync::Arc::new(runner.full_masks());

    let handles: Vec<_> = (0..8)
        .map(|i| {
            let (r, p, m) = (runner.clone(), params.clone(), masks.clone());
            std::thread::spawn(move || {
                let batch = random_batch(&r.spec, 100 + i);
                let out = r.train_step(&p, &m, &batch, 0.01).unwrap();
                assert!(out.loss.is_finite());
                out.loss
            })
        })
        .collect();
    let losses: Vec<f32> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(losses.len(), 8);
}
