//! Figure 7 (Appendix A.3) — training time is linear in sub-model size.
//!
//! For each of the five devices and three datasets, sweep r and fit
//! time(r) by OLS. The paper's claim (which FLuID's `r = 1/speedup`
//! sizing rule depends on): the relationship is linear and within 10% of
//! direct proportionality.
//!
//! Run: `cargo bench --bench fig7_linearity`

use fluid::coordinator::report;
use fluid::straggler::{mobile_fleet, FluctuationSchedule, PerfModel};
use fluid::util::prng::Pcg32;
use fluid::util::stats;

fn main() {
    let rates = [0.5, 0.65, 0.75, 0.85, 0.95, 1.0];
    let quiet = FluctuationSchedule::none();

    for model in ["femnist_cnn", "cifar_vgg9", "shakespeare_lstm"] {
        println!("== Fig 7: time vs sub-model size ({model}), % of full-model time ==\n");
        let pm = PerfModel {
            jitter_sigma: 0.0,
            ..PerfModel::new(model, 4_000_000)
        };
        let mut rows = Vec::new();
        let mut worst_dev = 0.0f64;
        for dev in mobile_fleet() {
            let mut rng = Pcg32::new(1, 1);
            let t_full = pm.compute_time(&dev, 0, 1.0, 0.0, &quiet, &mut rng);
            let mut row = vec![dev.name.clone()];
            let mut xs = Vec::new();
            let mut ys = Vec::new();
            for &r in &rates {
                let t = pm.compute_time(&dev, 0, r, 0.0, &quiet, &mut rng);
                let frac = t / t_full;
                worst_dev = worst_dev.max((frac - r).abs());
                row.push(format!("{:.1}", frac * 100.0));
                xs.push(r);
                ys.push(frac);
            }
            let (_, slope, r2) = stats::linear_fit(&xs, &ys);
            row.push(format!("{slope:.3}"));
            row.push(format!("{r2:.4}"));
            rows.push(row);
        }
        let mut headers = vec!["device"];
        let labels: Vec<String> = rates.iter().map(|r| format!("r={r}")).collect();
        headers.extend(labels.iter().map(|s| s.as_str()));
        headers.push("slope");
        headers.push("R^2");
        println!("{}", report::text_table(&headers, &rows));
        println!(
            "max |time-fraction - r| across devices: {:.1}% (paper: within 10%)\n",
            worst_dev * 100.0
        );
    }
}
