//! Table 5 (Appendix A.6) — scalability with client sampling: FEMNIST,
//! 1000 clients, 10% sampled per round, sub-model sizes down to 0.40.
//!
//! Default runs 100 clients for speed; `--full` runs the paper's 1000.
//!
//! Run: `cargo bench --bench table5_sampling [-- --full]`

use fluid::bench::{experiments as exp, full_mode};
use fluid::coordinator::report;
use fluid::dropout::PolicyKind;

fn main() {
    let full = full_mode();
    let sess = exp::session_or_exit();
    let clients = if full { 1000 } else { 100 };
    let rates: Vec<f64> = if full {
        vec![0.95, 0.85, 0.75, 0.65, 0.40]
    } else {
        vec![0.95, 0.75, 0.40]
    };

    println!(
        "== Table 5: FEMNIST, {clients} clients, 10% client sampling per round ==\n"
    );
    let mut rows = Vec::new();
    for (pname, policy) in [
        ("Random", PolicyKind::Random),
        ("Ordered", PolicyKind::Ordered),
        ("Invariant", PolicyKind::Invariant),
    ] {
        let mut row = vec![pname.to_string()];
        for &r in &rates {
            let mut cfg = exp::scale_config("femnist_cnn", policy, clients, r, full);
            cfg.sample_fraction = 0.1;
            cfg.samples_per_client = if full { 20 } else { 16 };
            cfg.rounds = if full { 50 } else { 12 };
            cfg.eval_every = cfg.rounds;
            cfg.recalibrate_every = 1; // re-detect within every sampled cohort
            match exp::single(&sess, &cfg) {
                Ok(res) => row.push(format!("{:.1}", res.final_test_acc * 100.0)),
                Err(e) => {
                    eprintln!("{pname}@r={r}: {e:#}");
                    row.push("ERR".into());
                }
            }
        }
        rows.push(row);
    }
    let mut headers = vec!["method"];
    let labels: Vec<String> = rates.iter().map(|r| format!("r={r}")).collect();
    headers.extend(labels.iter().map(|s| s.as_str()));
    println!("{}", report::text_table(&headers, &rows));
    println!("\nExpected shape: Invariant maintains the best accuracy profile at every r");
    println!("even with sampling (paper: 88.1/88.2/88.0/87.7/87.2).");
}
