//! Table 2 — accuracy of Random / Ordered / Invariant dropout across
//! sub-model sizes r ∈ {0.95, 0.85, 0.75, 0.65, 0.5} on the three
//! datasets (5 mobile clients, 1 straggler at fixed r).
//!
//! Default mode runs FEMNIST with a reduced rate set; `--full` runs all
//! three datasets x five rates x 5 seeds (paper scale, CPU-hours).
//! Expected *shape*: Invariant >= Ordered and Invariant >= Random at
//! equal r, with significance checked by Welch's t-test (α < 0.05).
//!
//! Run: `cargo bench --bench table2_accuracy [-- --full] [--seeds N]`

use fluid::bench::{experiments as exp, full_mode, seed_count};
use fluid::coordinator::report;
use fluid::dropout::PolicyKind;
use fluid::util::stats;

fn main() {
    let full = full_mode();
    let seeds = seed_count();
    let sess = exp::session_or_exit();

    let models: Vec<&str> = if full {
        vec!["shakespeare_lstm", "cifar_vgg9", "femnist_cnn"]
    } else {
        vec!["femnist_cnn"]
    };
    let rates: Vec<f64> = if full {
        vec![0.95, 0.85, 0.75, 0.65, 0.5]
    } else {
        vec![0.95, 0.75, 0.5]
    };
    let policies = [
        ("Random", PolicyKind::Random),
        ("Ordered", PolicyKind::Ordered),
        ("Invariant", PolicyKind::Invariant),
    ];

    println!(
        "== Table 2: accuracy (mean ± std over {seeds} seeds) ==\n   models: {models:?}, rates: {rates:?}\n"
    );
    for model in &models {
        println!("--- {model} ---");
        let mut rows = Vec::new();
        // per (policy, r): store the raw accs for significance testing
        let mut raw: Vec<Vec<Vec<f64>>> = vec![vec![Vec::new(); rates.len()]; policies.len()];
        for (pi, (pname, policy)) in policies.iter().enumerate() {
            let mut row = vec![pname.to_string()];
            for (ri, &r) in rates.iter().enumerate() {
                let cfg = exp::table2_config(model, *policy, r, full);
                match exp::accuracy_over_seeds(&sess, &cfg, seeds) {
                    Ok((mu, sigma, accs)) => {
                        row.push(report::mean_std(mu, sigma));
                        raw[pi][ri] = accs;
                    }
                    Err(e) => {
                        eprintln!("run failed: {e:#}");
                        row.push("ERR".into());
                    }
                }
            }
            rows.push(row);
        }
        let mut headers = vec!["method"];
        let rate_labels: Vec<String> = rates.iter().map(|r| format!("r={r}")).collect();
        headers.extend(rate_labels.iter().map(|s| s.as_str()));
        println!("{}", report::text_table(&headers, &rows));

        // significance: Invariant vs Ordered per rate
        for (ri, &r) in rates.iter().enumerate() {
            let (inv, ord) = (&raw[2][ri], &raw[1][ri]);
            if inv.len() >= 2 && ord.len() >= 2 {
                let (_, p) = stats::welch_t_test(inv, ord);
                let delta = (stats::mean(inv) - stats::mean(ord)) * 100.0;
                println!(
                    "  r={r}: Invariant - Ordered = {delta:+.2} pp (Welch p = {p:.3}{})",
                    if p < 0.05 { ", significant" } else { "" }
                );
            }
        }
        println!();
    }
    println!("Expected shape: Invariant >= Ordered, Invariant >= Random at equal r;");
    println!("accuracy decreases as r shrinks (paper: max gain 1.4-1.6 pp).");
}
