//! Table 4 (Appendix A.4) — clustering stragglers into four sub-model
//! size groups {0.65, 0.75, 0.85, 0.95} instead of forcing one size.
//!
//! Run: `cargo bench --bench table4_clusters [-- --full]`

use fluid::bench::{experiments as exp, full_mode, seed_count};
use fluid::coordinator::report;
use fluid::dropout::PolicyKind;

fn main() {
    let full = full_mode();
    let seeds = seed_count().min(2);
    let sess = exp::session_or_exit();
    let models: Vec<&str> = if full {
        vec!["cifar_vgg9", "femnist_cnn", "shakespeare_lstm"]
    } else {
        vec!["femnist_cnn"]
    };
    let clients = if full { 50 } else { 25 };
    let clusters = vec![0.65, 0.75, 0.85, 0.95];

    println!(
        "== Table 4: straggler clusters {clusters:?} ({clients} clients, 20% stragglers) ==\n"
    );
    let mut rows = Vec::new();
    for model in &models {
        let mut row = vec![model.to_string()];
        for (pname, policy) in [
            ("Random", PolicyKind::Random),
            ("Ordered", PolicyKind::Ordered),
            ("Invariant", PolicyKind::Invariant),
        ] {
            // FLuID sizes each straggler from its own speedup, snapped to
            // the cluster menu (fixed_rate = None => per-straggler rates)
            let mut cfg = exp::scale_config(model, policy, clients, 0.75, full);
            cfg.fixed_rate = None;
            cfg.cluster_rates = Some(clusters.clone());
            match exp::accuracy_over_seeds(&sess, &cfg, seeds) {
                Ok((mu, _, _)) => row.push(format!("{:.1}", mu * 100.0)),
                Err(e) => {
                    eprintln!("{model}/{pname}: {e:#}");
                    row.push("ERR".into());
                }
            }
            let _ = pname;
        }
        rows.push(row);
    }
    println!(
        "{}",
        report::text_table(&["dataset", "Random", "Ordered", "Invariant"], &rows)
    );
    println!("\nExpected shape: Invariant highest per dataset (paper: 72.7 / 78.2 / 54.1);");
    println!("clustered accuracy lands between the all-0.75 and all-0.85 runs.");
}
