//! Figure 6 (Appendix A.1) — evolution of invariant neurons.
//!
//! Trains the global model federated (no dropout, so every client votes)
//! and tracks the fraction of neurons whose relative update stays below a
//! fixed per-dataset threshold as rounds progress. The paper's claim:
//! after ~30% of training, 15-30% of neurons are already invariant.
//!
//! Run: `cargo bench --bench fig6_invariant_evolution [-- --full]`

use fluid::bench::{experiments as exp, full_mode};
use fluid::coordinator::{report, ExperimentConfig};
use fluid::dropout::PolicyKind;

fn main() {
    let full = full_mode();
    let sess = exp::session_or_exit();

    // paper's example thresholds: 180% (CIFAR10), 10% (FEMNIST), 500%
    // (Shakespeare) relative change
    let setups: Vec<(&str, f32)> = if full {
        vec![
            ("cifar_vgg9", 1.8),
            ("femnist_cnn", 0.10),
            ("shakespeare_lstm", 5.0),
        ]
    } else {
        vec![("femnist_cnn", 0.10)]
    };

    for (model, th) in &setups {
        let mut cfg = ExperimentConfig::mobile(model, PolicyKind::Invariant);
        cfg.rounds = if full { 30 } else { 15 };
        cfg.samples_per_client = 40;
        cfg.local_steps = 3;
        cfg.lr = exp::tuned_lr(model);
        cfg.eval_every = cfg.rounds;
        cfg.invariant_th_override = Some(*th);
        // full-size masks: we only *measure* invariance here, so keep the
        // straggler on the full model by snapping every rate to 1.0
        cfg.fixed_rate = Some(1.0);

        println!(
            "== Fig 6: % invariant neurons over training ({model}, th={}%) ==\n",
            th * 100.0
        );
        let res = exp::single(&sess, &cfg).unwrap();
        let rows: Vec<Vec<String>> = res
            .records
            .iter()
            .map(|r| {
                let progress = (r.round + 1) as f64 / cfg.rounds as f64;
                vec![
                    r.round.to_string(),
                    format!("{:.0}%", progress * 100.0),
                    format!("{:.1}%", r.invariant_fraction * 100.0),
                ]
            })
            .collect();
        println!(
            "{}",
            report::text_table(&["round", "training progress", "invariant neurons"], &rows)
        );
        // the paper's claim at the 30% mark
        let idx = (cfg.rounds as f64 * 0.3) as usize;
        if let Some(r) = res.records.get(idx) {
            println!(
                "at 30% of training: {:.1}% invariant (paper: 15-30%)\n",
                r.invariant_fraction * 100.0
            );
        }
    }
}
