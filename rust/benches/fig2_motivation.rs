//! Figure 2 — motivation.
//!
//! (a) per-epoch training time of the five mobile clients across the
//!     three datasets (paper plots these in log scale; we print the
//!     values plus the straggler/next-slowest ratio).
//! (b) accuracy cost of a *static* prior technique (Ordered Dropout)
//!     versus vanilla FL as the sub-model shrinks.
//!
//! Run: `cargo bench --bench fig2_motivation [-- --full]`

use fluid::bench::{experiments as exp, full_mode, seed_count};
use fluid::coordinator::report;
use fluid::dropout::PolicyKind;
use fluid::straggler::{mobile_fleet, FluctuationSchedule, PerfModel};
use fluid::util::prng::Pcg32;

fn main() {
    let full = full_mode();
    let sess = exp::session_or_exit();

    // ---- (a) device heterogeneity -----------------------------------------
    println!("== Fig 2a: per-epoch training time per device (seconds) ==\n");
    let fleet = mobile_fleet();
    let quiet = FluctuationSchedule::none();
    let mut rows = Vec::new();
    for dev in &fleet {
        let mut row = vec![dev.name.clone()];
        for model in ["femnist_cnn", "cifar_vgg9", "shakespeare_lstm"] {
            let pm = PerfModel::new(model, 4_000_000);
            let mut rng = Pcg32::new(7, 7);
            let t = pm.compute_time(dev, 0, 1.0, 0.0, &quiet, &mut rng);
            row.push(format!("{t:.2}"));
        }
        rows.push(row);
    }
    println!(
        "{}",
        report::text_table(&["device", "FEMNIST", "CIFAR10", "Shakespeare"], &rows)
    );
    for (i, model) in ["femnist_cnn", "cifar_vgg9", "shakespeare_lstm"].iter().enumerate()
    {
        let mut times: Vec<f64> = fleet.iter().map(|d| d.base_time(model)).collect();
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        println!(
            "  {model}: straggler / next-slowest = {:.2}x  (paper: 1.10-1.32x)",
            times[4] / times[3]
        );
        let _ = i;
    }

    // ---- (b) static dropout hurts accuracy ---------------------------------
    println!("\n== Fig 2b: Ordered Dropout vs vanilla FL (test accuracy %) ==\n");
    let rates = if full {
        vec![1.0, 0.95, 0.85, 0.75, 0.65, 0.5]
    } else {
        vec![1.0, 0.75, 0.5]
    };
    let seeds = seed_count();
    let mut rows = Vec::new();
    for &r in &rates {
        let policy = if r >= 1.0 {
            PolicyKind::None
        } else {
            PolicyKind::Ordered
        };
        let cfg = exp::table2_config("femnist_cnn", policy, r, full);
        let (mu, sigma, _) = exp::accuracy_over_seeds(&sess, &cfg, seeds).unwrap();
        rows.push(vec![
            if r >= 1.0 { "baseline (r=1.0)".into() } else { format!("ordered r={r}") },
            report::mean_std(mu, sigma),
        ]);
    }
    println!("{}", report::text_table(&["system", "accuracy %"], &rows));
    println!("\nExpected shape: accuracy degrades as r shrinks (paper: up to 2.5pp).");
}
