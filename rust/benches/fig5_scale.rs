//! Figure 5 (+ Fig 4c/4d) — accuracy at 50-100 clients with the slowest
//! 20% as stragglers, including the "exclude stragglers" baseline the
//! paper's scale study compares against.
//!
//! Since the fleet refactor this bench also exercises the *population*
//! regime first: a 50k-client fleet (5k in quick mode) with 256 clients
//! sampled per round under a scripted churn+drift scenario, run through
//! the runtime-free simulation backend — no artifacts needed, so the
//! fleet section always runs, and its throughput (descriptor bookkeeping,
//! sampling, lazy hydration, virtual timing, masked FedAvg) is the thing
//! being measured.
//!
//! Run: `cargo bench --bench fig5_scale [-- --full] [--seeds N]`

use fluid::bench::{experiments as exp, full_mode, seed_count};
use fluid::coordinator::{self, report, ExperimentConfig};
use fluid::dropout::PolicyKind;
use fluid::engine::ScenarioConfig;
use std::time::Instant;

fn fleet_section(full: bool) {
    let fleet_size = if full { 50_000 } else { 5_000 };
    let sample_k = 256;
    let mut cfg = ExperimentConfig::fleet(
        "femnist_cnn",
        PolicyKind::Invariant,
        fleet_size,
        sample_k,
    );
    cfg.rounds = if full { 12 } else { 6 };
    cfg.samples_per_client = 8;
    cfg.local_steps = 1;
    cfg.eval_every = cfg.rounds;
    cfg.scenario = ScenarioConfig::parse("storm").expect("preset parses");

    println!(
        "== Fleet scale: {fleet_size} clients, {sample_k}/round, storm scenario (sim backend) ==\n"
    );
    let t0 = Instant::now();
    match coordinator::run_sim(&cfg) {
        Ok(res) => {
            let wall = t0.elapsed().as_secs_f64();
            let rows: Vec<Vec<String>> = res
                .records
                .iter()
                .map(|r| {
                    vec![
                        r.round.to_string(),
                        r.cohort.len().to_string(),
                        r.straggler_ids.len().to_string(),
                        format!("{:.1}", r.round_time),
                        format!("{}", r.aggregated),
                    ]
                })
                .collect();
            println!(
                "{}",
                report::text_table(
                    &["round", "cohort", "stragglers", "t_round s", "aggregated"],
                    &rows
                )
            );
            let client_rounds: usize =
                res.records.iter().map(|r| r.cohort.len()).sum();
            println!(
                "wall {wall:.2}s  vtime {:.0}s  {:.0} client-rounds/s\n",
                res.total_vtime,
                client_rounds as f64 / wall.max(1e-9)
            );
        }
        Err(e) => eprintln!("fleet section failed: {e:#}"),
    }
}

fn main() {
    let full = full_mode();
    let seeds = seed_count().min(2);

    // population regime first: needs no artifacts
    fleet_section(full);

    // classic Fig-5 accuracy study over real artifacts
    let sess = exp::session_or_exit();

    let setups: Vec<(&str, usize)> = if full {
        vec![
            ("shakespeare_lstm", 50),
            ("cifar_vgg9", 100),
            ("femnist_cnn", 100),
            ("cifar_resnet18", 100),
        ]
    } else {
        vec![("femnist_cnn", 50)]
    };
    let policies = [
        ("Random", PolicyKind::Random),
        ("Ordered", PolicyKind::Ordered),
        ("Invariant", PolicyKind::Invariant),
        ("Exclude", PolicyKind::Exclude),
    ];
    let r = 0.75;

    println!(
        "== Fig 5: accuracy at scale (20% stragglers, r={r}, {seeds} seeds) ==\n"
    );
    for (model, clients) in &setups {
        println!("--- {model}, {clients} clients ---");
        let mut rows = Vec::new();
        for (pname, policy) in &policies {
            let cfg = exp::scale_config(model, *policy, *clients, r, full);
            match exp::accuracy_over_seeds(&sess, &cfg, seeds) {
                Ok((mu, sigma, _)) => {
                    rows.push(vec![pname.to_string(), report::mean_std(mu, sigma)])
                }
                Err(e) => {
                    eprintln!("{pname} failed: {e:#}");
                    rows.push(vec![pname.to_string(), "ERR".into()]);
                }
            }
        }
        println!("{}", report::text_table(&["method", "accuracy %"], &rows));
        println!();
    }
    println!("Expected shape: Invariant highest; Exclude (drop stragglers' data) lowest.");
}
