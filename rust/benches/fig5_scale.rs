//! Figure 5 (+ Fig 4c/4d) — accuracy at 50-100 clients with the slowest
//! 20% as stragglers, including the "exclude stragglers" baseline the
//! paper's scale study compares against.
//!
//! Run: `cargo bench --bench fig5_scale [-- --full] [--seeds N]`

use fluid::bench::{experiments as exp, full_mode, seed_count};
use fluid::coordinator::report;
use fluid::dropout::PolicyKind;

fn main() {
    let full = full_mode();
    let seeds = seed_count().min(2);
    let sess = exp::session_or_exit();

    let setups: Vec<(&str, usize)> = if full {
        vec![
            ("shakespeare_lstm", 50),
            ("cifar_vgg9", 100),
            ("femnist_cnn", 100),
            ("cifar_resnet18", 100),
        ]
    } else {
        vec![("femnist_cnn", 50)]
    };
    let policies = [
        ("Random", PolicyKind::Random),
        ("Ordered", PolicyKind::Ordered),
        ("Invariant", PolicyKind::Invariant),
        ("Exclude", PolicyKind::Exclude),
    ];
    let r = 0.75;

    println!(
        "== Fig 5: accuracy at scale (20% stragglers, r={r}, {seeds} seeds) ==\n"
    );
    for (model, clients) in &setups {
        println!("--- {model}, {clients} clients ---");
        let mut rows = Vec::new();
        for (pname, policy) in &policies {
            let cfg = exp::scale_config(model, *policy, *clients, r, full);
            match exp::accuracy_over_seeds(&sess, &cfg, seeds) {
                Ok((mu, sigma, _)) => {
                    rows.push(vec![pname.to_string(), report::mean_std(mu, sigma)])
                }
                Err(e) => {
                    eprintln!("{pname} failed: {e:#}");
                    rows.push(vec![pname.to_string(), "ERR".into()]);
                }
            }
        }
        println!("{}", report::text_table(&["method", "accuracy %"], &rows));
        println!();
    }
    println!("Expected shape: Invariant highest; Exclude (drop stragglers' data) lowest.");
}
