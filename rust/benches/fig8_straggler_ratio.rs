//! Figure 8 (Appendix A.5) — accuracy as the straggler ratio grows from
//! 10% to 40% of the fleet (0.75 sub-models).
//!
//! Run: `cargo bench --bench fig8_straggler_ratio [-- --full]`

use fluid::bench::{experiments as exp, full_mode, seed_count};
use fluid::coordinator::report;
use fluid::dropout::PolicyKind;

fn main() {
    let full = full_mode();
    let seeds = seed_count().min(2);
    let sess = exp::session_or_exit();
    let models: Vec<(&str, usize)> = if full {
        vec![("shakespeare_lstm", 50), ("cifar_vgg9", 100), ("femnist_cnn", 100)]
    } else {
        vec![("femnist_cnn", 30)]
    };
    let ratios = [0.1, 0.2, 0.3, 0.4];

    for (model, clients) in &models {
        println!(
            "== Fig 8: accuracy vs straggler ratio ({model}, {clients} clients, r=0.75) ==\n"
        );
        let mut rows = Vec::new();
        for (pname, policy) in [
            ("Random", PolicyKind::Random),
            ("Ordered", PolicyKind::Ordered),
            ("Invariant", PolicyKind::Invariant),
        ] {
            let mut row = vec![pname.to_string()];
            for &ratio in &ratios {
                let mut cfg = exp::scale_config(model, policy, *clients, 0.75, full);
                cfg.straggler_fraction = ratio;
                match exp::accuracy_over_seeds(&sess, &cfg, seeds) {
                    Ok((mu, _, _)) => row.push(format!("{:.1}", mu * 100.0)),
                    Err(e) => {
                        eprintln!("{pname}@{ratio}: {e:#}");
                        row.push("ERR".into());
                    }
                }
            }
            rows.push(row);
        }
        println!(
            "{}",
            report::text_table(&["method", "10%", "20%", "30%", "40%"], &rows)
        );
        println!("\nExpected shape: accuracy decreases as the ratio grows; Invariant stays highest.\n");
    }
}
