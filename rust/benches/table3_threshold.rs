//! Table 3 (Appendix A.2) — threshold value vs percentage of invariant
//! neurons vs final accuracy (FEMNIST, sub-model size 0.75).
//!
//! Run: `cargo bench --bench table3_threshold [-- --full]`

use fluid::bench::{experiments as exp, full_mode};
use fluid::coordinator::report;
use fluid::dropout::PolicyKind;
use fluid::util::stats;

fn main() {
    let full = full_mode();
    let sess = exp::session_or_exit();
    let thresholds: Vec<f32> = if full {
        vec![0.01, 0.03, 0.05, 0.07, 0.08, 0.10]
    } else {
        vec![0.01, 0.05, 0.10]
    };

    println!("== Table 3: threshold vs invariant neurons vs accuracy (FEMNIST, r=0.75) ==\n");
    let mut rows = Vec::new();
    for &th in &thresholds {
        let mut cfg = exp::table2_config("femnist_cnn", PolicyKind::Invariant, 0.75, full);
        cfg.invariant_th_override = Some(th);
        let res = exp::single(&sess, &cfg).unwrap();
        // mean invariant fraction over the second half of training
        let half = res.records.len() / 2;
        let inv = stats::mean(
            &res.records[half..]
                .iter()
                .map(|r| r.invariant_fraction)
                .collect::<Vec<_>>(),
        );
        rows.push(vec![
            format!("{:.0}", th * 100.0),
            format!("{:.0}", inv * 100.0),
            format!("{:.2}", res.final_test_acc * 100.0),
        ]);
    }
    println!(
        "{}",
        report::text_table(
            &["threshold (%)", "invariant neurons (%)", "accuracy (%)"],
            &rows
        )
    );
    println!("\nExpected shape: higher threshold -> more invariant neurons (paper: 3%..31%);");
    println!("accuracy peaks when #invariant ~= #neurons dropped (25% at r=0.75).");
}
