//! Figure 4a/4b — FLuID's performance effect.
//!
//! (a) straggler training time before (full model) and after FLuID
//!     (auto-sized sub-model) vs the target time, per dataset.
//! (b) total training time with stragglers *changing at runtime*
//!     (background load at the 25/50/75% marks): vanilla FL vs FLuID
//!     with a static straggler choice vs dynamic FLuID.
//!
//! Run: `cargo bench --bench fig4_performance [-- --full]`

use fluid::bench::{experiments as exp, full_mode};
use fluid::coordinator::{report, ExperimentConfig};
use fluid::dropout::PolicyKind;
use fluid::util::stats;

fn main() {
    let full = full_mode();
    let sess = exp::session_or_exit();
    let models: Vec<&str> = if full {
        vec!["femnist_cnn", "cifar_vgg9", "shakespeare_lstm"]
    } else {
        vec!["femnist_cnn"]
    };

    // ---- (a) straggler time before/after ------------------------------------
    println!("== Fig 4a: straggler round time vs target (virtual seconds) ==\n");
    let mut rows = Vec::new();
    for model in &models {
        let mut cfg = ExperimentConfig::mobile(model, PolicyKind::Invariant);
        cfg.rounds = if full { 16 } else { 8 };
        cfg.samples_per_client = 30;
        cfg.local_steps = 2;
        cfg.lr = exp::tuned_lr(model);
        cfg.eval_every = cfg.rounds;
        let res = exp::single(&sess, &cfg).unwrap();
        // "before": round 0 runs everyone on the full model
        let before = res.records[0].round_time;
        // "after": steady-state straggler time + target
        let steady: Vec<&fluid::coordinator::RoundRecord> = res
            .records
            .iter()
            .skip(3)
            .filter(|r| !r.straggler_ids.is_empty())
            .collect();
        let t_target = stats::mean(&steady.iter().map(|r| r.t_target).collect::<Vec<_>>());
        let after = stats::mean(
            &steady.iter().map(|r| r.straggler_time).collect::<Vec<_>>(),
        );
        rows.push(vec![
            model.to_string(),
            format!("{before:.2}"),
            format!("{after:.2}"),
            format!("{t_target:.2}"),
            format!("{:+.1}%", (after / t_target - 1.0) * 100.0),
        ]);
    }
    println!(
        "{}",
        report::text_table(
            &["dataset", "straggler before", "straggler after", "T_target", "after vs target"],
            &rows
        )
    );
    println!("Expected: before is 10-32% above target; after lands within ~10%.\n");

    // ---- (b) runtime fluctuation ---------------------------------------------
    println!("== Fig 4b: total training time with stragglers changing at runtime ==\n");
    let mut rows = Vec::new();
    for model in &models {
        let mk = |policy: PolicyKind, static_s: bool| {
            let mut cfg = ExperimentConfig::mobile(model, policy);
            cfg.rounds = if full { 24 } else { 12 };
            cfg.samples_per_client = 30;
            cfg.local_steps = 2;
            cfg.lr = exp::tuned_lr(model);
            cfg.eval_every = cfg.rounds;
            cfg.fluctuation = true;
            cfg.static_stragglers = static_s;
            cfg
        };
        let vanilla = exp::single(&sess, &mk(PolicyKind::None, false)).unwrap();
        let stat = exp::single(&sess, &mk(PolicyKind::Invariant, true)).unwrap();
        let dynamic = exp::single(&sess, &mk(PolicyKind::Invariant, false)).unwrap();
        rows.push(vec![
            model.to_string(),
            format!("{:.1}", vanilla.total_vtime),
            format!("{:.1}", stat.total_vtime),
            format!("{:.1}", dynamic.total_vtime),
            format!("{:.1}%", (1.0 - dynamic.total_vtime / vanilla.total_vtime) * 100.0),
            format!("{:.1}%", (1.0 - dynamic.total_vtime / stat.total_vtime) * 100.0),
        ]);
    }
    println!(
        "{}",
        report::text_table(
            &[
                "dataset",
                "vanilla",
                "FLuID static",
                "FLuID dynamic",
                "dyn vs vanilla",
                "dyn vs static"
            ],
            &rows
        )
    );
    println!("Expected shape: dynamic fastest (paper: 18-26% vs baseline, 14-18% vs static).");
}
