//! Runtime hot-path microbenchmarks (§Perf of EXPERIMENTS.md).
//!
//! Two tiers of sections:
//!
//! * **Pure sections** — run in every build configuration, including
//!   `--no-default-features` on CI: masked FedAvg aggregation, invariant
//!   mask extraction, fleet cohort sampling at 50k AND 1M clients (with
//!   an in-bench sub-linear scaling gate pinning the 1M/50k cost ratio),
//!   scenario churn at both scales, a full sim-backend fleet round, the
//!   MitigationPolicy planning dispatch on a 50k fleet (DESIGN.md §14),
//!   the sharded aggregator tree at 50k (with an in-bench gate pinning the
//!   4-shard round to <= 1.25x the single-engine round, DESIGN.md §11),
//!   the shard wire codec round trip, the update-payload codec (sparse
//!   encode / q8 decode at ~50k params, with an in-bench gate pinning
//!   sparse wire bytes at rate 0.5 to <= 0.6x dense, DESIGN.md §12),
//!   payload-aware FedAvg, the chaos-plane update validator and the
//!   shard-fault retry re-dispatch (with an in-bench gate pinning the
//!   zero-chaos sharded round to <= 1.05x its pre-chaos bound,
//!   DESIGN.md §13), and snapshot encode/decode.
//! * **PJRT sections** — `train_step` / `eval_step` / `delta_step` per
//!   model, tensor→literal conversion, and one full coordinator round;
//!   these need AOT artifacts and skip cleanly when the session cannot
//!   open (stub builds, fresh checkouts).
//!
//! Machine-readable output + CI gating:
//!
//! ```sh
//! cargo bench --bench hotpath [-- --full] \
//!     [--json BENCH_hotpath.json]          # write ns/op per section
//!     [--check BENCH_baseline.json]        # fail on >tolerance regression
//!     [--tolerance 0.25]
//!     [--summary summary.md]               # append a markdown delta table
//!                                          # (CI: $GITHUB_STEP_SUMMARY)
//! ```
//!
//! The check compares each section's best (min) ns/op against the
//! committed baseline and exits non-zero when any section regresses by
//! more than the tolerance. Sections absent from the baseline warn;
//! baseline entries with `min_ns <= 0` are treated as unseeded and
//! skipped.

use fluid::bench::{full_mode, Bench, Measurement};
use fluid::coordinator::{self, ExperimentConfig};
use fluid::data::FlData;
use fluid::dropout::{InvariantConfig, InvariantDropout, MaskSet, PolicyKind};
use fluid::engine::ScenarioConfig;
use fluid::fl::{
    fedavg_into, sample_cohort, AggScratch, AggregateMode, ClientUpdate, Codec, Compression,
    DeltaPayload, Fleet, SamplerKind, UpdateCodec,
};
use fluid::jsonlite::{self, Json};
use fluid::model::{sim_spec, ModelSpec};
use fluid::runtime::Session;
use fluid::snapshot::{PolicyState, Snapshot};
use fluid::tensor::Tensor;
use fluid::util::pool::default_threads;
use fluid::util::prng::Pcg32;

fn arg_value(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn main() {
    let b = if full_mode() {
        Bench::new(5, 30)
    } else {
        Bench::new(2, 8)
    };
    let mut all: Vec<Measurement> = Vec::new();

    println!("== hot path microbenchmarks ==\n");
    pure_benches(&b, &mut all);
    pjrt_benches(&b, &mut all);

    if let Some(path) = arg_value("--json") {
        let json = to_json(&all);
        if let Err(e) = std::fs::write(&path, json.to_string_pretty()) {
            eprintln!("writing {path}: {e}");
            std::process::exit(1);
        }
        println!("wrote {path} ({} sections)", all.len());
    }
    if let Some(baseline) = arg_value("--check") {
        let tol: f64 = arg_value("--tolerance")
            .and_then(|t| t.parse().ok())
            .unwrap_or(0.25);
        std::process::exit(check_against(&all, &baseline, tol, arg_value("--summary")));
    }
}

/// An LSTM-shaped manifest: the `lstm` group's weight uses the 4H gate
/// layout (trailing dim = 4 x hidden), exactly the column->neuron
/// mapping the ownership denominator factorization must handle.
fn lstm_spec(hidden: usize) -> ModelSpec {
    let gates = 4 * hidden;
    let fc = hidden / 2;
    let manifest = format!(
        r#"{{
 "model": "bench_lstm", "batch_size": 8,
 "x_shape": [8, 16], "x_dtype": "f32", "num_classes": 10,
 "params": [
   {{"name": "lstm_w", "shape": [128, {gates}]}}, {{"name": "lstm_b", "shape": [{gates}]}},
   {{"name": "fc_w", "shape": [{hidden}, {fc}]}}, {{"name": "fc_b", "shape": [{fc}]}},
   {{"name": "out_w", "shape": [{fc}, 10]}}, {{"name": "out_b", "shape": [10]}}
 ],
 "masks": [{{"name": "lstm", "size": {hidden}}}, {{"name": "fc", "size": {fc}}}],
 "delta_groups": ["lstm", "fc"],
 "delta_inputs": ["lstm_w", "fc_w"],
 "artifacts": {{"train": "sim", "eval": "sim", "delta": "sim"}},
 "train_outputs": []
}}"#
    );
    ModelSpec::from_json_str(&manifest, std::path::Path::new("/"))
        .expect("bench manifest is statically valid")
}

/// The payload-codec bench model: one masked [192, 256] weight + [256]
/// bias pair, ~50k parameters — big enough that framing cost is real,
/// one group so the sparse/dense wire ratio at keep-rate 0.5 is a clean
/// ~0.5 (plus fixed headers).
fn codec_spec() -> ModelSpec {
    let manifest = r#"{
 "model": "bench_codec", "batch_size": 8,
 "x_shape": [8, 16], "x_dtype": "f32", "num_classes": 10,
 "params": [
   {"name": "fc0_w", "shape": [192, 256]}, {"name": "fc0_b", "shape": [256]}
 ],
 "masks": [{"name": "fc0", "size": 256}],
 "delta_groups": ["fc0"],
 "delta_inputs": ["fc0_w"],
 "artifacts": {"train": "sim", "eval": "sim", "delta": "sim"},
 "train_outputs": []
}"#;
    ModelSpec::from_json_str(manifest, std::path::Path::new("/"))
        .expect("bench manifest is statically valid")
}

/// A 64-update cohort over `spec`; every fourth client is a straggler
/// whose mask keeps the first 75% of each group (so the ownership path
/// exercises real dropped columns, not the all-kept fast case).
fn bench_updates(spec: &ModelSpec, n: usize) -> Vec<ClientUpdate> {
    (0..n)
        .map(|i| {
            let mask = if i % 4 == 3 {
                let keep: Vec<Vec<bool>> = spec
                    .masks
                    .iter()
                    .map(|m| (0..m.size).map(|j| j < m.size * 3 / 4).collect())
                    .collect();
                MaskSet::from_keep(spec, &keep)
            } else {
                MaskSet::full(spec)
            };
            ClientUpdate {
                payload: DeltaPayload::DenseF32(spec.init_params(100 + i as u64)),
                weight: 16.0,
                mask,
                staleness: 0,
            }
        })
        .collect()
}

// ---- pure sections (any build configuration) -------------------------------

fn pure_benches(b: &Bench, all: &mut Vec<Measurement>) {
    let spec = sim_spec("femnist_cnn");
    let threads = default_threads();
    let mut scratch = AggScratch::new();

    // masked FedAvg over a cohort-sized update set, through the pooled
    // hot path exactly as the engine runs it (arena reused across calls)
    let global = spec.init_params(2);
    let updates = bench_updates(&spec, 64);
    let m = b.run("aggregate/fedavg-plain-64", || {
        let out =
            fedavg_into(&spec, &global, &updates, AggregateMode::Plain, threads, &mut scratch);
        std::hint::black_box(out.len());
        scratch.recycle(out);
    });
    println!("{}", m.report());
    all.push(m);
    let m = b.run("aggregate/fedavg-ownership-64", || {
        let out = fedavg_into(
            &spec,
            &global,
            &updates,
            AggregateMode::OwnershipWeighted,
            threads,
            &mut scratch,
        );
        std::hint::black_box(out.len());
        scratch.recycle(out);
    });
    println!("{}", m.report());
    all.push(m);

    // LSTM-shaped aggregation: the 4H gate layout stresses the expanded
    // kept-column weight vectors and the row-streaming sweep
    let lspec = lstm_spec(256);
    let lglobal = lspec.init_params(2);
    let lupdates = bench_updates(&lspec, 64);
    let m = b.run("aggregate/fedavg-lstm-64", || {
        let out = fedavg_into(
            &lspec,
            &lglobal,
            &lupdates,
            AggregateMode::OwnershipWeighted,
            threads,
            &mut scratch,
        );
        std::hint::black_box(out.len());
        scratch.recycle(out);
    });
    println!("{}", m.report());
    all.push(m);

    // fused observation sweep over LSTM-sized neuron groups (16 voters)
    {
        let ospec = lstm_spec(4096);
        let mut inv = InvariantDropout::new(&ospec, InvariantConfig::default());
        let mut rng = Pcg32::new(9, 2);
        let odeltas: Vec<Vec<Tensor>> = (0..16)
            .map(|_| {
                ospec
                    .masks
                    .iter()
                    .map(|m| {
                        Tensor::from_vec(
                            &[m.size],
                            (0..m.size).map(|_| rng.next_f32() * 0.2).collect(),
                        )
                    })
                    .collect()
            })
            .collect();
        inv.observe_with(&odeltas, threads, &mut scratch); // init thresholds
        let m = b.run("invariant/observe-lstm-16v", || {
            inv.observe_with(&odeltas, threads, &mut scratch);
            std::hint::black_box(inv.invariant_fraction());
        });
        println!("{}", m.report());
        all.push(m);
    }

    // invariant mask extraction
    let mut inv = InvariantDropout::new(&spec, InvariantConfig::default());
    let mut rng = Pcg32::new(5, 5);
    let deltas: Vec<Vec<Tensor>> = (0..8)
        .map(|_| {
            spec.masks
                .iter()
                .map(|m| {
                    Tensor::from_vec(
                        &[m.size],
                        (0..m.size).map(|_| rng.next_f32() * 0.2).collect(),
                    )
                })
                .collect()
        })
        .collect();
    inv.observe(&deltas);
    let m = b.run("invariant/make-mask", || {
        let mask = inv.make_mask(&spec, 0.75);
        std::hint::black_box(mask.keep_fraction());
    });
    println!("{}", m.report());
    all.push(m);

    // fleet cohort sampling at population scale: the same draw at 50k
    // and at 1M clients. The incremental sampler is O(k log n) per draw,
    // so the honest 1M/50k ratio is ~1.3x plus cache effects; an
    // accidental O(fleet) regression is >=20x. The scaling gate below
    // asserts the ratio stays under SCALE_GATE.
    const SCALE_GATE: f64 = 10.0;
    let mut fleet = Fleet::synthetic_pool(50_000, 7);
    fleet.set_data_lens((0..50_000).map(|c| 4 + c % 13));
    let mut fleet_1m = Fleet::synthetic_pool(1_000_000, 7);
    fleet_1m.set_data_lens((0..1_000_000).map(|c| 4 + c % 13));
    let mut scale_pairs: Vec<(String, f64, f64)> = Vec::new();
    for (name_50k, name_1m, kind) in [
        ("fleet/sample-uniform-50k", "fleet/sample-uniform-1m", SamplerKind::Uniform),
        (
            "fleet/sample-weighted-50k",
            "fleet/sample-weighted-1m",
            SamplerKind::WeightedByData,
        ),
        (
            "fleet/sample-available-50k",
            "fleet/sample-available-1m",
            SamplerKind::AvailabilityAware,
        ),
    ] {
        let mut srng = Pcg32::new(11, 3);
        let m50 = b.run(name_50k, || {
            let s = sample_cohort(&mut fleet, kind, 256, &mut srng);
            std::hint::black_box(s.len());
        });
        println!("{}", m50.report());
        let m1m = b.run(name_1m, || {
            let s = sample_cohort(&mut fleet_1m, kind, 256, &mut srng);
            std::hint::black_box(s.len());
        });
        println!("{}", m1m.report());
        scale_pairs.push((name_1m.to_string(), m50.min_s, m1m.min_s));
        all.push(m50);
        all.push(m1m);
    }
    // sub-linear scaling gate (ISSUE 6 acceptance): per-round sampling
    // cost must not grow with the fleet
    for (name, s50, s1m) in &scale_pairs {
        let ratio = s1m / s50.max(1e-12);
        println!("scale {name}: 1m/50k min ratio {ratio:.2} (gate {SCALE_GATE:.0}x)");
        assert!(
            ratio < SCALE_GATE,
            "{name}: 20x more clients cost {ratio:.1}x (gate {SCALE_GATE:.0}x) — \
             per-round sampling is no longer O(cohort log fleet)"
        );
    }

    // adaptive rate-controller recalibration over a 2k-client pool
    // (EWMA profiles + promotion/demotion + feedback steps + the
    // Detection assembly the engine consumes every calibration round)
    {
        use fluid::straggler::{AdaptConfig, AdaptMode, RateController};
        let n = 2000usize;
        let mut ctl = RateController::new(
            n,
            AdaptConfig { mode: AdaptMode::Ewma, ..AdaptConfig::default() },
        );
        let mut crng = Pcg32::new(17, 4);
        let pool: Vec<usize> = (0..n).collect();
        let full: Vec<f64> = (0..n)
            .map(|_| 10.0 * crng.lognormal(0.35) as f64)
            .collect();
        let mut tick = 0u64;
        let m = b.run("adapt/controller-step-2k", || {
            // fresh arrivals every tick so the EWMA/step paths stay hot
            let wobble = 1.0 + 0.01 * (tick % 7) as f64;
            for c in 0..n {
                let f = full[c] * wobble;
                let r = ctl.rate_of(c);
                ctl.observe(c, f * r, f, r);
            }
            tick += 1;
            let det = ctl.recalibrate(&pool, &full, 0.2, 0.02, &[]).unwrap();
            std::hint::black_box(det.stragglers.len());
        });
        println!("{}", m.report());
        all.push(m);
    }

    // scenario churn tick over the whole population
    let sim = fluid::engine::ScenarioSim::new(
        ScenarioConfig::parse("storm").unwrap().unwrap(),
        42,
    );
    let mut round = 0usize;
    let m = b.run("scenario/churn-50k", || {
        sim.apply_churn(round, &mut fleet);
        round += 1;
        std::hint::black_box(fleet.num_available());
    });
    println!("{}", m.report());
    all.push(m);

    // churn as sparse deltas at 1M clients: cost is O(expected flips ·
    // log n) — storm rates flip ~10% of the population, but there is no
    // O(fleet) sweep, no per-client PRNG draw, and no reallocation
    let mut round_1m = 0usize;
    let m = b.run("fleet/churn-delta-1m", || {
        sim.apply_churn(round_1m, &mut fleet_1m);
        round_1m += 1;
        std::hint::black_box(fleet_1m.num_available());
    });
    println!("{}", m.report());
    all.push(m);

    // the full per-round fleet overhead a 1M experiment pays outside of
    // training: availability-aware cohort draw + churn delta
    let mut orng = Pcg32::new(13, 5);
    let m = b.run("fleet/round-overhead-1m", || {
        let s = sample_cohort(&mut fleet_1m, SamplerKind::AvailabilityAware, 256, &mut orng);
        std::hint::black_box(s.len());
        sim.apply_churn(round_1m, &mut fleet_1m);
        round_1m += 1;
    });
    println!("{}", m.report());
    all.push(m);

    // one full fleet round trip through the sim backend
    let mut cfg = ExperimentConfig::fleet("femnist_cnn", PolicyKind::Invariant, 2000, 32);
    cfg.rounds = 2;
    cfg.samples_per_client = 4;
    cfg.local_steps = 1;
    cfg.eval_every = cfg.rounds;
    cfg.scenario = ScenarioConfig::parse("storm").unwrap();
    let m = b.run("sim/fleet-2k-2rounds", || {
        let res = coordinator::run_sim(&cfg).unwrap();
        std::hint::black_box(res.total_vtime);
    });
    println!("{}", m.report());
    all.push(m);

    // MitigationPolicy seam dispatch (DESIGN.md §14): one planning call
    // on a 50k fleet through the boxed trait object — straggler
    // recalibration over the measured 256-cohort plus invariant-path
    // sub-model assignment. The full invariant round stays gated by
    // sharded/round-50k below (seeded pre-seam, so the refactor itself
    // is regression-checked); this section isolates the per-round
    // planning dispatch the seam added.
    let pspec = sim_spec("femnist_cnn");
    let pcfg = ExperimentConfig::fleet("femnist_cnn", PolicyKind::Invariant, 50_000, 256);
    let mut mit = fluid::policy::build(&pcfg, &pspec, 50_000);
    let pfull = MaskSet::full(&pspec);
    // a measured fleet with a deterministic latency spread, cohort
    // spanning the id range
    let plat: Vec<f64> = (0..50_000).map(|c| 1.0 + (c % 97) as f64 * 0.01).collect();
    let pselected: Vec<usize> = (0..256).map(|i| i * 195).collect();
    let mut pround = 1usize;
    let m = b.run("policy/dispatch-50k", || {
        let a = mit.plan(fluid::policy::PlanCtx {
            round: pround,
            selected: &pselected,
            fleet_mode: true,
            last_full_latencies: &plat,
            spec: &pspec,
            full_mask: &pfull,
        });
        pround += 1;
        std::hint::black_box(a.straggler_ids.len());
    });
    println!("{}", m.report());
    all.push(m);

    // sharded multi-aggregator tree (DESIGN.md §11): the same 50k storm
    // fleet run once on the plain executor and once split across 4 shard
    // workers. The output is bit-identical by construction (pinned in
    // tests/sharded_determinism.rs); here the *cost* is pinned — the
    // shard wire encode/decode plus the root's ordered re-fold must stay
    // within SHARD_GATE of the single-engine round, or the tree is
    // paying for copies the pooled codec was designed to avoid. Both
    // legs pay the identical 50k fleet setup, so the ratio isolates the
    // per-round sharding overhead conservatively.
    const SHARD_GATE: f64 = 1.25;
    let mut scfg = ExperimentConfig::fleet("femnist_cnn", PolicyKind::Invariant, 50_000, 256);
    scfg.rounds = 3;
    scfg.samples_per_client = 4;
    scfg.local_steps = 1;
    scfg.eval_every = scfg.rounds;
    scfg.scenario = ScenarioConfig::parse("storm").unwrap();
    scfg.seed = 20_260_729;
    let m1 = b.run("sharded/round-50k", || {
        let res = coordinator::run_sim(&scfg).unwrap();
        std::hint::black_box(res.total_vtime);
    });
    println!("{}", m1.report());
    scfg.shards = 4;
    let m4 = b.run("sharded/round-4shard-50k", || {
        let res = coordinator::run_sim(&scfg).unwrap();
        std::hint::black_box(res.total_vtime);
    });
    println!("{}", m4.report());
    let ratio = m4.min_s / m1.min_s.max(1e-12);
    println!("sharded: 4-shard/single min ratio {ratio:.2} (gate {SHARD_GATE:.2}x)");
    assert!(
        ratio < SHARD_GATE,
        "4-shard 50k round costs {ratio:.2}x the single-engine round (gate {SHARD_GATE:.2}x) \
         — the shard wire/fold overhead is no longer O(message)"
    );
    let m1_min_ns = m1.min_s * 1e9;
    all.push(m1);
    all.push(m4);

    // zero-chaos overhead gate (DESIGN.md §13): the chaos plane is
    // always compiled in — the validator runs on every update and the
    // executor carries the retry bookkeeping — so the clean sharded
    // round above must stay within CHAOS_GATE of the bound the section
    // was seeded with before the chaos plane existed. A breach means
    // the zero-chaos path started paying for fault machinery it never
    // uses (per-client draws, allocation in the validator, ...).
    const PRE_CHAOS_ROUND_BOUND_NS: f64 = 2_000_000_000.0;
    const CHAOS_GATE: f64 = 1.05;
    let clean_ns = m1_min_ns;
    println!(
        "chaos: zero-chaos sharded round {clean_ns:.0} ns vs pre-chaos bound \
         {PRE_CHAOS_ROUND_BOUND_NS:.0} ns (gate {CHAOS_GATE:.2}x)"
    );
    assert!(
        clean_ns < CHAOS_GATE * PRE_CHAOS_ROUND_BOUND_NS,
        "zero-chaos sharded round costs {clean_ns:.0} ns, over {CHAOS_GATE:.2}x the \
         pre-chaos bound {PRE_CHAOS_ROUND_BOUND_NS:.0} ns — the fault plane is taxing \
         clean rounds"
    );

    // shard-fault recovery: a 4-shard round where one worker slice dies
    // and the bounded retry budget re-dispatches it — the marginal cost
    // of recovery is (one extra slice run + wire round trip), pinned
    // here so redispatch never silently becomes O(round)
    {
        use fluid::data::{Split, XStore};
        use fluid::engine::{ShardedExecutor, SimExecutor, TrainJob};
        use fluid::fl::Client;
        let rspec = sim_spec("femnist_cnn");
        let rparams = rspec.init_params(7);
        let full = MaskSet::full(&rspec);
        let rclients: Vec<Client> = (0..16)
            .map(|i| {
                Client::new(
                    i,
                    0,
                    Split {
                        xs: XStore::F32(vec![0.0; 4 * (i + 2)]),
                        ys: vec![0; i + 2],
                        feature_len: 4,
                    },
                )
            })
            .collect();
        let cohort: Vec<&Client> = rclients.iter().collect();
        let masks: Vec<&MaskSet> = rclients.iter().map(|_| &full).collect();
        let jobs: Vec<TrainJob> = rclients
            .iter()
            .map(|c| TrainJob {
                client: c.id,
                round: 2,
                steps: 2,
                lr: 0.05,
                seed: 1234,
                use_fused: false,
            })
            .collect();
        let m = b.run("sharded/retry-redispatch", || {
            // fresh executor per iteration so the crash re-arms and the
            // retry path runs every time (fire-once state is per-tree)
            let ex = ShardedExecutor::with_fault(
                SimExecutor::new(rspec.clone(), threads),
                4,
                Some((2, 2)),
                true,
            );
            let got = ex.run_clients(&cohort, &masks, &rparams, &jobs);
            std::hint::black_box(got.len());
        });
        println!("{}", m.report());
        all.push(m);
    }

    // shard wire codec round trip with warm buffers: a realistic
    // 16-client slice (a 64x32 weight + 32-bias pair each) through
    // encode_message/decode_message, columns recycled through the
    // scratch pool exactly as the root does per round (the alloc gate in
    // tests/alloc_gate.rs pins this path to O(message) shells)
    {
        use fluid::engine::wire::{decode_message, encode_message, ShardMessage};
        use fluid::fl::LocalResult;
        let items: Vec<Result<LocalResult, String>> = (0..16)
            .map(|i| {
                Ok(LocalResult {
                    params: vec![
                        Tensor::from_vec(&[64, 32], vec![0.5 + i as f32; 64 * 32]),
                        Tensor::from_vec(&[32], vec![1.0; 32]),
                    ],
                    mean_loss: 0.25,
                    mean_acc: 0.5,
                    steps: 4,
                    weight: 6.0,
                })
            })
            .collect();
        let msg = ShardMessage::Results { shard: 1, round: 9, base: 32, items };
        let (mut blob, mut frame) = (Vec::new(), Vec::new());
        encode_message(&msg, &mut blob, &mut frame);
        let m = b.run("sharded/wire-encode-decode", || {
            encode_message(&msg, &mut blob, &mut frame);
            let decoded = decode_message(&frame, &mut scratch).unwrap();
            if let ShardMessage::Results { items, .. } = decoded {
                for r in items.into_iter().flatten() {
                    scratch.recycle(r.params);
                }
            }
            std::hint::black_box(frame.len());
        });
        println!("{}", m.report());
        all.push(m);
    }

    // update-payload codec (DESIGN.md §12): a ~50k-parameter layer pair
    // ([192, 256] weight + [256] bias) packed at keep-rate 0.5. Encode
    // is the engine-side seam (mask-pack + wire framing), decode the
    // root-side one (frame parse into payload vectors). The in-bench
    // gate pins the contract the compressed modes exist for: sparse
    // wire bytes at rate 0.5 must stay under 0.6x the dense framing.
    {
        use fluid::fl::codec::{put_payload, take_payload};
        use fluid::snapshot::{Reader, Writer};
        const WIRE_GATE: f64 = 0.6;
        let cspec = codec_spec();
        let cglobal = cspec.init_params(2);
        let keep: Vec<Vec<bool>> = cspec
            .masks
            .iter()
            .map(|m| (0..m.size).map(|j| j % 2 == 0).collect())
            .collect();
        let cmask = MaskSet::from_keep(&cspec, &keep);
        let cparams = cspec.init_params(9);

        let dense_wire = DeltaPayload::DenseF32(cparams.clone()).wire_bytes();
        let mut sparse_codec = Codec::new(Compression::Sparse);
        let sparse_wire = sparse_codec
            .encode(0, cparams.clone(), &cmask, &cglobal, &cspec, &mut scratch)
            .wire_bytes();
        let ratio = sparse_wire as f64 / dense_wire as f64;
        println!(
            "codec: sparse {sparse_wire} B / dense {dense_wire} B at rate 0.5 = \
             {ratio:.3} (gate {WIRE_GATE:.2})"
        );
        assert!(
            ratio <= WIRE_GATE,
            "sparse wire framing moves {ratio:.3}x the dense bytes at keep-rate 0.5 \
             (gate {WIRE_GATE:.2}x) — the packed encoding is no longer O(kept)"
        );

        let m = b.run("codec/encode-sparse-50k", || {
            let payload = sparse_codec.encode(
                1,
                cparams.clone(),
                &cmask,
                &cglobal,
                &cspec,
                &mut scratch,
            );
            let mut wtr = Writer::new();
            put_payload(&mut wtr, &payload);
            std::hint::black_box(wtr.into_bytes().len());
        });
        println!("{}", m.report());
        all.push(m);

        let q8_frame = {
            let mut q8_codec = Codec::new(Compression::Q8);
            let payload =
                q8_codec.encode(2, cparams.clone(), &cmask, &cglobal, &cspec, &mut scratch);
            let mut wtr = Writer::new();
            put_payload(&mut wtr, &payload);
            wtr.into_bytes()
        };
        let m = b.run("codec/decode-q8-50k", || {
            let payload = take_payload(&mut Reader::new(&q8_frame), &mut scratch).unwrap();
            std::hint::black_box(payload.wire_bytes());
        });
        println!("{}", m.report());
        all.push(m);
    }

    // update validation (DESIGN.md §13): the full finiteness + shape +
    // norm-bound sweep over a ~50k-parameter update, exactly as the
    // engine runs it on every arrival — clean path, zero allocation
    // (pinned in tests/alloc_gate.rs), cost must stay O(params)
    {
        use fluid::engine::UpdateValidator;
        use fluid::fl::LocalResult;
        let vspec = codec_spec();
        let base = vspec.init_params(2);
        let update = LocalResult {
            params: vspec.init_params(9),
            mean_loss: 0.25,
            mean_acc: 0.5,
            steps: 4,
            weight: 6.0,
        };
        let validator = UpdateValidator::default();
        let m = b.run("chaos/validate-50k", || {
            let verdict = validator.validate(&update, &base);
            std::hint::black_box(verdict.is_ok());
        });
        println!("{}", m.report());
        all.push(m);
    }

    // payload-aware FedAvg: the same 64-update cohort as the dense
    // sections, but entering the aggregator as sparse payloads (the
    // fused unpack-accumulate path compressed experiments run)
    {
        let mut enc = Codec::new(Compression::Sparse);
        let sparse_updates: Vec<ClientUpdate> = (0..64)
            .map(|i| {
                let mask = if i % 4 == 3 {
                    let keep: Vec<Vec<bool>> = spec
                        .masks
                        .iter()
                        .map(|m| (0..m.size).map(|j| j < m.size * 3 / 4).collect())
                        .collect();
                    MaskSet::from_keep(&spec, &keep)
                } else {
                    MaskSet::full(&spec)
                };
                let payload = enc.encode(
                    i as u64,
                    spec.init_params(100 + i as u64),
                    &mask,
                    &global,
                    &spec,
                    &mut scratch,
                );
                ClientUpdate { payload, weight: 16.0, mask, staleness: 0 }
            })
            .collect();
        let m = b.run("aggregate/fedavg-sparse-64", || {
            let out = fedavg_into(
                &spec,
                &global,
                &sparse_updates,
                AggregateMode::OwnershipWeighted,
                threads,
                &mut scratch,
            );
            std::hint::black_box(out.len());
            scratch.recycle(out);
        });
        println!("{}", m.report());
        all.push(m);
    }

    // snapshot codec over a representative mid-run state
    let snap = synthetic_snapshot(&spec, 2000, 50);
    let m = b.run("snapshot/encode-2k-fleet", || {
        let bytes = snap.encode();
        std::hint::black_box(bytes.len());
    });
    println!("{}", m.report());
    all.push(m);
    let bytes = snap.encode();
    let m = b.run("snapshot/decode-2k-fleet", || {
        let back = Snapshot::decode(&bytes).unwrap();
        std::hint::black_box(back.next_round);
    });
    println!("{}", m.report());
    all.push(m);
    println!();
}

/// A mid-run-shaped snapshot: sim-spec params, a 2k-client availability
/// map, and a 50-round history.
fn synthetic_snapshot(
    spec: &fluid::model::ModelSpec,
    clients: usize,
    rounds: usize,
) -> Snapshot {
    let (th, streak, score, observations) = {
        let mut inv = InvariantDropout::new(spec, InvariantConfig::default());
        let deltas: Vec<Vec<Tensor>> = (0..4)
            .map(|c| {
                spec.masks
                    .iter()
                    .map(|m| Tensor::full(&[m.size], 0.01 * (c + 1) as f32))
                    .collect()
            })
            .collect();
        inv.observe(&deltas);
        inv.export_state()
    };
    Snapshot {
        fingerprint: "bench".into(),
        next_round: rounds,
        vtime: 1234.5,
        calib_total: 0.5,
        train_wall: 9.0,
        params: spec.init_params(3),
        policy: PolicyState::Invariant { th, streak, score, observations },
        availability: (0..clients).map(|i| i % 7 != 0).collect(),
        detection: None,
        ctrl: None,
        last_latencies: (0..clients).map(|i| i as f64 * 0.001).collect(),
        last_full_latencies: (0..clients).map(|i| i as f64 * 0.0015).collect(),
        free_at: vec![0.0; clients],
        stale: Vec::new(),
        resid: Vec::new(),
        zoo: None,
        quarantine: (0..4)
            .map(|i| fluid::engine::QuarEntry {
                client: i * 17 + 3,
                strikes: 1 + i as u32,
                barred_until: rounds + i,
                last_strike: rounds.saturating_sub(2),
            })
            .collect(),
        records: (0..rounds)
            .map(|r| fluid::coordinator::RoundRecord {
                round: r,
                round_time: 3.0,
                vtime: 3.0 * (r + 1) as f64,
                cohort: (0..32).collect(),
                straggler_ids: vec![5, 9],
                straggler_rates: vec![0.75, 0.65],
                t_target: 2.5,
                straggler_time: 3.0,
                train_loss: 1.0,
                train_acc: 0.5,
                test_loss: f64::NAN,
                test_acc: f64::NAN,
                invariant_fraction: 0.1,
                calibration_secs: 0.001,
                aggregated: 32,
                dropped_updates: 0,
                stale_folded: 0,
                update_bytes: 0,
                vanished: 0,
                quarantined: 0,
                shard_retries: 0,
                quorum_fraction: 1.0,
                straggler_wait: 0.5,
                admitted_stale: 0,
                soft_fraction: 1.0,
            })
            .collect(),
    }
}

// ---- PJRT sections (need artifacts) ----------------------------------------

fn pjrt_benches(b: &Bench, all: &mut Vec<Measurement>) {
    let sess = match Session::new(Session::default_dir()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping PJRT sections (no session: {e:#})\n");
            return;
        }
    };
    let models: Vec<&str> = if full_mode() {
        vec!["femnist_cnn", "cifar_vgg9", "shakespeare_lstm", "cifar_resnet18"]
    } else {
        vec!["femnist_cnn", "shakespeare_lstm"]
    };
    for model in &models {
        step_benches(&sess, model, b, all);
    }
    coordinator_round_bench(&sess, b, all);
}

fn random_batch(spec: &fluid::model::ModelSpec, seed: u64) -> fluid::runtime::Batch {
    let data = FlData::for_model(&spec.name, 1, spec.batch_size.max(8), seed);
    let mut rng = Pcg32::new(seed, 3);
    data.clients[0].sample_batch(&mut rng, &spec.x_shape)
}

fn step_benches(sess: &Session, model: &str, b: &Bench, all: &mut Vec<Measurement>) {
    let runner = match sess.runner(model) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("skipping {model}: {e:#}");
            return;
        }
    };
    let params = runner.spec.init_params(1);
    let masks = runner.full_masks();
    let batch = random_batch(&runner.spec, 11);

    let m = b.run(&format!("{model}/train_step"), || {
        let out = runner.train_step(&params, &masks, &batch, 0.01).unwrap();
        std::hint::black_box(out.loss);
    });
    println!("{}", m.report());
    all.push(m);
    let m = b.run(&format!("{model}/eval_step"), || {
        let out = runner.eval_step(&params, &masks, &batch).unwrap();
        std::hint::black_box(out.loss);
    });
    println!("{}", m.report());
    all.push(m);
    // fused k-step program (§Perf L2 optimization) vs k single steps
    if runner.multi_k() > 0 {
        let k = runner.multi_k();
        let batches: Vec<fluid::runtime::Batch> =
            (0..k).map(|i| random_batch(&runner.spec, 50 + i as u64)).collect();
        let m = b.run(&format!("{model}/train_multi-fused-k{k}"), || {
            let out = runner.train_multi_step(&params, &masks, &batches, 0.01).unwrap();
            std::hint::black_box(out.loss);
        });
        println!("{}", m.report());
        all.push(m);
        let m = b.run(&format!("{model}/train_step-x{k}-sequential"), || {
            let mut cur = params.clone();
            for bt in &batches {
                cur = runner.train_step(&cur, &masks, bt, 0.01).unwrap().params;
            }
            std::hint::black_box(cur.len());
        });
        println!("{}", m.report());
        all.push(m);
    }

    let new_params = runner.train_step(&params, &masks, &batch, 0.05).unwrap().params;
    let m = b.run(&format!("{model}/delta_step"), || {
        let d = runner.delta_step(&params, &new_params).unwrap();
        std::hint::black_box(d.len());
    });
    println!("{}", m.report());
    all.push(m);

    // conversion cost for the largest parameter (PJRT builds only)
    #[cfg(feature = "xla")]
    {
        let biggest = params.iter().max_by_key(|t| t.len()).unwrap().clone();
        let m = b.run(&format!("{model}/tensor-to-literal"), || {
            let lit = fluid::runtime::tensor_to_literal(&biggest).unwrap();
            std::hint::black_box(&lit);
        });
        println!("{}", m.report());
        all.push(m);
    }
    println!();
}

fn coordinator_round_bench(sess: &Session, b: &Bench, all: &mut Vec<Measurement>) {
    let mut cfg = ExperimentConfig::mobile("femnist_cnn", PolicyKind::Invariant);
    cfg.rounds = 1;
    cfg.samples_per_client = 20;
    cfg.local_steps = 2;
    cfg.eval_every = 10; // skip eval inside the timed region
    let m = b.run("coordinator/full-round-5-clients", || {
        let res = coordinator::run(sess, &cfg).unwrap();
        std::hint::black_box(res.total_vtime);
    });
    println!("{}", m.report());
    all.push(m);
}

// ---- JSON emission + baseline gate -----------------------------------------

fn to_json(all: &[Measurement]) -> Json {
    let mut sections = Json::obj();
    for m in all {
        sections = sections.set(
            &m.name,
            Json::obj()
                .set("ns_per_op", m.mean_s * 1e9)
                .set("min_ns", m.min_s * 1e9)
                .set("std_ns", m.std_s * 1e9)
                .set("iters", m.iters),
        );
    }
    Json::obj()
        .set("bench", "hotpath")
        .set("mode", if full_mode() { "full" } else { "quick" })
        .set("sections", sections)
}

fn check_against(
    all: &[Measurement],
    baseline_path: &str,
    tol: f64,
    summary_path: Option<String>,
) -> i32 {
    let text = match std::fs::read_to_string(baseline_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("cannot read baseline {baseline_path}: {e}");
            return 2;
        }
    };
    let base = match jsonlite::parse(&text) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("cannot parse baseline {baseline_path}: {e:#}");
            return 2;
        }
    };
    let Some(sections) = base.get("sections").and_then(|s| s.as_obj()) else {
        eprintln!("baseline {baseline_path} has no sections object");
        return 2;
    };
    let mut regressions = 0usize;
    // per-section markdown delta table (CI appends it to the job summary)
    let mut md = String::from(
        "### hotpath bench vs baseline\n\n\
         | section | min ns/op | baseline ns | delta | status |\n\
         |---|---:|---:|---:|---|\n",
    );
    println!("== baseline gate (tolerance {:.0}%) ==", tol * 100.0);
    for m in all {
        let cur_ns = m.min_s * 1e9;
        let base_ns = sections
            .get(&m.name)
            .and_then(|s| s.get("min_ns"))
            .and_then(|v| v.as_f64());
        match base_ns {
            None => {
                println!("{:<42} {:>12.0} ns  (new section, no baseline)", m.name, cur_ns);
                md.push_str(&format!("| `{}` | {:.0} | — | — | new |\n", m.name, cur_ns));
            }
            Some(b) if b <= 0.0 => {
                println!("{:<42} {:>12.0} ns  (baseline unseeded)", m.name, cur_ns);
                md.push_str(&format!("| `{}` | {:.0} | — | — | unseeded |\n", m.name, cur_ns));
            }
            Some(b) => {
                let delta = cur_ns / b - 1.0;
                let flag = if delta > tol {
                    regressions += 1;
                    "REGRESSION"
                } else {
                    "ok"
                };
                println!(
                    "{:<42} {:>12.0} ns vs {:>12.0} ns  {:+6.1}%  {flag}",
                    m.name,
                    cur_ns,
                    b,
                    delta * 100.0
                );
                md.push_str(&format!(
                    "| `{}` | {:.0} | {:.0} | {:+.1}% | {} |\n",
                    m.name,
                    cur_ns,
                    b,
                    delta * 100.0,
                    if delta > tol { "**REGRESSION**" } else { "ok" }
                ));
            }
        }
    }
    if let Some(path) = summary_path {
        md.push_str(&format!("\ntolerance {:.0}%\n", tol * 100.0));
        use std::io::Write as _;
        let appended = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .and_then(|mut f| f.write_all(md.as_bytes()));
        if let Err(e) = appended {
            eprintln!("cannot append bench summary to {path}: {e}");
        }
    }
    // Surface baseline rot: a seeded section that did not run this time
    // (renamed, dropped, or needs an environment this runner lacks —
    // e.g. PJRT sections on a stub build). Warn rather than fail so a
    // baseline seeded on an artifact-capable machine still gates stub
    // CI, but a rename can never silently shed its baseline.
    let ran: Vec<&str> = all.iter().map(|m| m.name.as_str()).collect();
    let mut missing = 0usize;
    for name in sections.keys() {
        if !ran.contains(&name.as_str()) {
            eprintln!("warning: baseline section {name:?} did not run (renamed or skipped?)");
            missing += 1;
        }
    }
    if missing > 0 {
        eprintln!(
            "warning: {missing} baseline section(s) unmatched — update BENCH_baseline.json \
             if sections were renamed"
        );
    }
    if regressions > 0 {
        eprintln!("{regressions} hot-path section(s) regressed more than {:.0}%", tol * 100.0);
        1
    } else {
        println!("no regressions beyond {:.0}%", tol * 100.0);
        0
    }
}
