//! Runtime hot-path microbenchmarks (§Perf of EXPERIMENTS.md).
//!
//! Times the building blocks every experiment is made of:
//! * `train_step` / `eval_step` / `delta_step` PJRT executions per model
//! * tensor <-> literal conversion
//! * masked FedAvg aggregation (plain vs ownership-weighted)
//! * invariant mask extraction
//! * one full coordinator round (5 clients)
//!
//! Run: `cargo bench --bench hotpath [-- --full]`

use fluid::bench::{experiments as exp, full_mode, Bench};
use fluid::coordinator::{self, ExperimentConfig};
use fluid::data::FlData;
use fluid::dropout::{InvariantConfig, InvariantDropout, MaskSet};
use fluid::fl::{fedavg, AggregateMode, ClientUpdate};
use fluid::dropout::PolicyKind;
use fluid::runtime::Session;
use fluid::tensor::Tensor;
use fluid::util::prng::Pcg32;

fn main() {
    let sess = exp::session_or_exit();
    let b = if full_mode() {
        Bench::new(5, 30)
    } else {
        Bench::new(2, 8)
    };
    let models: Vec<&str> = if full_mode() {
        vec!["femnist_cnn", "cifar_vgg9", "shakespeare_lstm", "cifar_resnet18"]
    } else {
        vec!["femnist_cnn", "shakespeare_lstm"]
    };

    println!("== hot path microbenchmarks ==\n");
    for model in &models {
        step_benches(&sess, model, &b);
    }
    aggregation_benches(&sess, &b);
    coordinator_round_bench(&sess, &b);
}

fn random_batch(spec: &fluid::model::ModelSpec, seed: u64) -> fluid::runtime::Batch {
    let data = FlData::for_model(&spec.name, 1, spec.batch_size.max(8), seed);
    let mut rng = Pcg32::new(seed, 3);
    data.clients[0].sample_batch(&mut rng, &spec.x_shape)
}

fn step_benches(sess: &Session, model: &str, b: &Bench) {
    let runner = match sess.runner(model) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("skipping {model}: {e:#}");
            return;
        }
    };
    let params = runner.spec.init_params(1);
    let masks = runner.full_masks();
    let batch = random_batch(&runner.spec, 11);

    let m = b.run(&format!("{model}/train_step"), || {
        let out = runner.train_step(&params, &masks, &batch, 0.01).unwrap();
        std::hint::black_box(out.loss);
    });
    println!("{}", m.report());
    let m = b.run(&format!("{model}/eval_step"), || {
        let out = runner.eval_step(&params, &masks, &batch).unwrap();
        std::hint::black_box(out.loss);
    });
    println!("{}", m.report());
    // fused k-step program (§Perf L2 optimization) vs k single steps
    if runner.multi_k() > 0 {
        let k = runner.multi_k();
        let batches: Vec<fluid::runtime::Batch> =
            (0..k).map(|i| random_batch(&runner.spec, 50 + i as u64)).collect();
        let m = b.run(&format!("{model}/train_multi (k={k}, fused)"), || {
            let out = runner.train_multi_step(&params, &masks, &batches, 0.01).unwrap();
            std::hint::black_box(out.loss);
        });
        println!("{}", m.report());
        let m = b.run(&format!("{model}/train x{k} (sequential)"), || {
            let mut cur = params.clone();
            for bt in &batches {
                cur = runner.train_step(&cur, &masks, bt, 0.01).unwrap().params;
            }
            std::hint::black_box(cur.len());
        });
        println!("{}", m.report());
    }

    let new_params = runner.train_step(&params, &masks, &batch, 0.05).unwrap().params;
    let m = b.run(&format!("{model}/delta_step"), || {
        let d = runner.delta_step(&params, &new_params).unwrap();
        std::hint::black_box(d.len());
    });
    println!("{}", m.report());

    // conversion cost for the largest parameter (PJRT builds only)
    #[cfg(feature = "xla")]
    {
        let biggest = params
            .iter()
            .max_by_key(|t| t.len())
            .unwrap()
            .clone();
        let m = b.run(&format!("{model}/tensor->literal ({} f32)", biggest.len()), || {
            let lit = fluid::runtime::tensor_to_literal(&biggest).unwrap();
            std::hint::black_box(&lit);
        });
        println!("{}", m.report());
    }
    println!();
}

fn aggregation_benches(sess: &Session, b: &Bench) {
    let Ok(runner) = sess.runner("femnist_cnn") else { return };
    let spec = &runner.spec;
    let global = spec.init_params(2);
    let updates: Vec<ClientUpdate> = (0..5)
        .map(|i| ClientUpdate {
            params: spec.init_params(100 + i),
            weight: 60.0,
            mask: MaskSet::full(spec),
            staleness: 0,
        })
        .collect();
    let m = b.run("aggregate/fedavg plain (5 clients, 410k params)", || {
        let out = fedavg(spec, &global, &updates, AggregateMode::Plain);
        std::hint::black_box(out.len());
    });
    println!("{}", m.report());
    let m = b.run("aggregate/fedavg ownership (5 clients, 410k params)", || {
        let out = fedavg(spec, &global, &updates, AggregateMode::OwnershipWeighted);
        std::hint::black_box(out.len());
    });
    println!("{}", m.report());

    // invariant mask extraction
    let mut inv = InvariantDropout::new(spec, InvariantConfig::default());
    let mut rng = Pcg32::new(5, 5);
    let deltas: Vec<Vec<Tensor>> = (0..4)
        .map(|_| {
            spec.masks
                .iter()
                .map(|m| {
                    Tensor::from_vec(
                        &[m.size],
                        (0..m.size).map(|_| rng.next_f32() * 0.2).collect(),
                    )
                })
                .collect()
        })
        .collect();
    inv.observe(&deltas);
    let m = b.run("invariant/make_mask (200 neurons)", || {
        let mask = inv.make_mask(spec, 0.75);
        std::hint::black_box(mask.keep_fraction());
    });
    println!("{}", m.report());
    println!();
}

fn coordinator_round_bench(sess: &Session, b: &Bench) {
    let mut cfg = ExperimentConfig::mobile("femnist_cnn", PolicyKind::Invariant);
    cfg.rounds = 1;
    cfg.samples_per_client = 20;
    cfg.local_steps = 2;
    cfg.eval_every = 10; // skip eval inside the timed region
    let m = b.run("coordinator/full round (5 clients, 2 local steps)", || {
        let res = coordinator::run(sess, &cfg).unwrap();
        std::hint::black_box(res.total_vtime);
    });
    println!("{}", m.report());
}
